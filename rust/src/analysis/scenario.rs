//! Pass groups 1–2: scenario well-formedness (`SL-SCN-*`) and
//! cross-layer consistency (`SL-XLY-*`).
//!
//! [`lint_scenario`] runs both groups over a [`Scenario`] with no zoo
//! in sight — everything here is decidable from the file alone. The
//! fail-fast subsets live here too: [`session_gate`] (Error-level
//! checks enforced when a `Session` opens, restricted to conditions
//! that are also valid for the per-shard sub-scenarios the sharded
//! drive opens) and [`build_gate`] (enforced at
//! `ShardedServer::build`).

use std::collections::BTreeMap;
use std::collections::BTreeSet;

use crate::profiler::TaskProfile;
use crate::scenario::{
    Admission, Arrival, Expect, FaultProfile, Scenario, ShardAssignment, Sharding,
};
use crate::workload::Slo;

use super::{Diagnostic, Report};

/// Lint a scenario: well-formedness (group 1) + cross-layer
/// consistency (group 2). Pure — never panics, never touches a zoo.
pub fn lint_scenario(sc: &Scenario) -> Report {
    let mut r = Report::new();
    lint_tasks(sc, &mut r);
    lint_schedule(sc, &mut r);
    lint_universe(sc, &mut r);
    lint_arrival(sc, &mut r, true);
    lint_admission(&sc.admission, &mut r);
    lint_dispatch(sc, &mut r);
    lint_sharding_vs_tasks(&sc.sharding, &sc.tasks, &mut r);
    lint_faults(sc, &mut r);
    lint_cross_layer(sc, &mut r);
    lint_stitching(sc, &mut r);
    r
}

/// Error-level checks enforced when a [`crate::scenario::Session`]
/// opens for `phase`. Restricted to conditions that hold for per-shard
/// sub-scenarios too (filtered task list + schedule, original arrival/
/// sharding/planner blocks): duplicate tasks, tasks without a profile,
/// tasks without an SLO in this phase, malformed SLO bounds in this
/// phase, and nonpositive arrival parameters.
pub fn session_gate(
    sc: &Scenario,
    phase: usize,
    profiles: &BTreeMap<String, TaskProfile>,
) -> Report {
    let mut r = Report::new();
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    let slos = sc.schedule.get(phase);
    for (i, name) in sc.tasks.iter().enumerate() {
        if !seen.insert(name.as_str()) {
            r.push(Diagnostic::error(
                "SL-SCN-002",
                format!("tasks[{i}]"),
                format!("scenario lists task {name:?} more than once"),
            ));
            continue;
        }
        if !profiles.contains_key(name) {
            r.push(Diagnostic::error(
                "SL-FEA-001",
                format!("tasks[{i}]"),
                format!("scenario references unknown task {name:?} (no profile on this server)"),
            ));
        }
        match slos.and_then(|cfg| cfg.get(name)) {
            None => r.push(Diagnostic::error(
                "SL-SCN-004",
                format!("schedule[{phase}]"),
                format!("scenario phase {phase} has no SLO for task {name:?}"),
            )),
            Some(slo) => lint_slo_bounds(slo, &format!("schedule[{phase}].{name}"), &mut r),
        }
    }
    lint_arrival(sc, &mut r, false);
    r
}

/// Error-level checks enforced at `ShardedServer::build` (and again at
/// `run` for the fault profile, which arrives with the scenario rather
/// than the deployment): an explicit assignment must only name tasks the
/// servers can actually serve and keep shard indices inside the shard
/// count, and a non-default fault profile must be well-formed and name
/// only shards that exist. (`Sharding::shard_of` keeps its documented
/// wrap/fallback behavior for raw use; a *built* deployment rejects the
/// config instead.)
pub fn build_gate(
    sharding: &Sharding,
    profiles: &BTreeMap<String, TaskProfile>,
    faults: &FaultProfile,
) -> Report {
    let mut r = Report::new();
    let n = sharding.shards.max(1);
    if let ShardAssignment::Explicit(map) = &sharding.assignment {
        for (task, &shard) in map {
            if !profiles.contains_key(task) {
                r.push(Diagnostic::error(
                    "SL-SCN-008",
                    format!("sharding.map.{task}"),
                    format!("sharding map names unknown task {task:?}"),
                ));
            }
            if shard >= n {
                r.push(Diagnostic::error(
                    "SL-SCN-009",
                    format!("sharding.map.{task}"),
                    format!("shard index {shard} out of range for {n} shard(s)"),
                ));
            }
        }
    }
    if !faults.is_default() {
        lint_fault_shapes(faults, &mut r);
        lint_fault_shards(faults, sharding, &mut r);
    }
    r
}

// ---- group 1: well-formedness ---------------------------------------

fn lint_tasks(sc: &Scenario, r: &mut Report) {
    if sc.tasks.is_empty() {
        r.push(Diagnostic::error(
            "SL-SCN-001",
            "tasks",
            "scenario has an empty task list: nothing would be served",
        ));
    }
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    for (i, name) in sc.tasks.iter().enumerate() {
        if !seen.insert(name.as_str()) {
            r.push(Diagnostic::error(
                "SL-SCN-002",
                format!("tasks[{i}]"),
                format!("scenario lists task {name:?} more than once"),
            ));
        }
    }
}

fn lint_schedule(sc: &Scenario, r: &mut Report) {
    if sc.schedule.is_empty() {
        r.push(Diagnostic::error(
            "SL-SCN-003",
            "schedule",
            "scenario has an empty SLO schedule: no phase to serve",
        ));
        return;
    }
    for (phase, cfg) in sc.schedule.iter().enumerate() {
        for name in &sc.tasks {
            if !cfg.contains_key(name) {
                r.push(Diagnostic::error(
                    "SL-SCN-004",
                    format!("schedule[{phase}]"),
                    format!("phase {phase} has no SLO for task {name:?}"),
                ));
            }
        }
        for (name, slo) in cfg {
            lint_slo_bounds(slo, &format!("schedule[{phase}].{name}"), r);
        }
    }
}

// NaN bounds are Errors (every comparison against NaN is false, so a
// NaN SLO silently reports zero violations — the gate must refuse it).
// Merely *unsatisfiable* bounds (accuracy > 1, latency ≤ 0) are Warns:
// the engine legally serves them best-effort and judges them as
// violating — the "impossible SLO" experiments depend on that.
fn lint_slo_bounds(slo: &Slo, at: &str, r: &mut Report) {
    if slo.min_accuracy.is_nan() || slo.max_latency_ms.is_nan() {
        r.push(Diagnostic::error(
            "SL-SCN-012",
            at.to_string(),
            "NaN SLO bound: comparisons against NaN are all false, so violations \
             would go unreported",
        ));
        return;
    }
    if !(0.0..=1.0).contains(&slo.min_accuracy) {
        r.push(Diagnostic::warn(
            "SL-SCN-012",
            at.to_string(),
            format!(
                "min_accuracy {} outside [0, 1]: this SLO is unsatisfiable (or \
                 trivial) by construction",
                slo.min_accuracy
            ),
        ));
    }
    if slo.max_latency_ms <= 0.0 {
        r.push(Diagnostic::warn(
            "SL-SCN-012",
            at.to_string(),
            format!(
                "max_latency_ms {} is not positive: every served query will violate",
                slo.max_latency_ms
            ),
        ));
    }
}

fn lint_universe(sc: &Scenario, r: &mut Report) {
    for (i, slo) in sc.universe.iter().enumerate() {
        lint_slo_bounds(slo, &format!("universe[{i}]"), r);
    }
    if sc.universe.is_empty() {
        return; // Ψ derives from the schedule: superset by construction.
    }
    for (phase, cfg) in sc.schedule.iter().enumerate() {
        for (name, slo) in cfg {
            if !sc.universe.iter().any(|u| u == slo) {
                r.push(Diagnostic::error(
                    "SL-SCN-005",
                    format!("schedule[{phase}].{name}"),
                    format!(
                        "SLO (acc ≥ {}, lat ≤ {} ms) served in phase {phase} is missing \
                         from the explicit universe Ψ: the preloader would never \
                         optimize for it",
                        slo.min_accuracy, slo.max_latency_ms
                    ),
                ));
            }
        }
    }
}

/// Arrival-process parameter checks. `full` adds the trace-content
/// checks that only make sense for the top-level scenario (the sharded
/// drive routes one shared stream, so per-shard sub-scenarios legally
/// carry trace entries for other shards' tasks).
fn lint_arrival(sc: &Scenario, r: &mut Report, full: bool) {
    let bad = |x: f64| !x.is_finite() || x <= 0.0;
    match &sc.arrival {
        Arrival::ClosedLoop { queries, stagger_ms } => {
            if *queries == 0 {
                r.push(Diagnostic::warn(
                    "SL-SCN-013",
                    "arrival",
                    "closed loop with 0 queries per task: the run is empty",
                ));
            }
            if !stagger_ms.is_finite() || *stagger_ms < 0.0 {
                r.push(Diagnostic::error(
                    "SL-SCN-006",
                    "arrival.stagger_ms",
                    format!("stagger_ms {stagger_ms} must be finite and ≥ 0"),
                ));
            }
        }
        Arrival::PoissonOpenLoop { rate_qps, horizon_ms } => {
            if bad(*rate_qps) {
                r.push(Diagnostic::error(
                    "SL-SCN-006",
                    "arrival.rate_qps",
                    format!("rate_qps {rate_qps} must be finite and > 0"),
                ));
            }
            if bad(*horizon_ms) {
                r.push(Diagnostic::error(
                    "SL-SCN-006",
                    "arrival.horizon_ms",
                    format!("horizon_ms {horizon_ms} must be finite and > 0"),
                ));
            }
        }
        Arrival::Bursty { base_qps, burst_qps, period_ms, horizon_ms } => {
            if !base_qps.is_finite() || *base_qps < 0.0 {
                r.push(Diagnostic::error(
                    "SL-SCN-006",
                    "arrival.base_qps",
                    format!("base_qps {base_qps} must be finite and ≥ 0"),
                ));
            }
            if bad(*burst_qps) {
                r.push(Diagnostic::error(
                    "SL-SCN-006",
                    "arrival.burst_qps",
                    format!("burst_qps {burst_qps} must be finite and > 0"),
                ));
            }
            if bad(*period_ms) {
                r.push(Diagnostic::error(
                    "SL-SCN-006",
                    "arrival.period_ms",
                    format!("period_ms {period_ms} must be finite and > 0"),
                ));
            }
            if bad(*horizon_ms) {
                r.push(Diagnostic::error(
                    "SL-SCN-006",
                    "arrival.horizon_ms",
                    format!("horizon_ms {horizon_ms} must be finite and > 0"),
                ));
            }
        }
        Arrival::Trace(queries) => {
            if !full {
                return;
            }
            if queries.is_empty() {
                r.push(Diagnostic::warn(
                    "SL-SCN-013",
                    "arrival",
                    "empty trace: the run is empty",
                ));
            }
            let tasks: BTreeSet<&str> = sc.tasks.iter().map(String::as_str).collect();
            let mut last_arrival: BTreeMap<&str, f64> = BTreeMap::new();
            for (i, q) in queries.iter().enumerate() {
                if !q.arrival_ms.is_finite() || q.arrival_ms < 0.0 {
                    r.push(Diagnostic::error(
                        "SL-SCN-006",
                        format!("arrival.queries[{i}]"),
                        format!("arrival_ms {} must be finite and ≥ 0", q.arrival_ms),
                    ));
                }
                if !tasks.contains(q.task.as_str()) {
                    r.push(Diagnostic::error(
                        "SL-SCN-011",
                        format!("arrival.queries[{i}]"),
                        format!("trace query {} targets task {:?} not in the scenario", q.id, q.task),
                    ));
                } else if let Some(&prev) = last_arrival.get(q.task.as_str()) {
                    if q.arrival_ms < prev {
                        r.push(Diagnostic::warn(
                            "SL-SCN-011",
                            format!("arrival.queries[{i}]"),
                            format!(
                                "trace arrivals for task {:?} go back in time \
                                 ({} ms after {} ms): FIFO order follows trace \
                                 position, not arrival stamps",
                                q.task, q.arrival_ms, prev
                            ),
                        ));
                    }
                }
                last_arrival.insert(q.task.as_str(), q.arrival_ms);
            }
        }
    }
}

fn lint_admission(adm: &Admission, r: &mut Report) {
    let bad = |x: f64| !x.is_finite() || x <= 0.0;
    match adm {
        Admission::Always | Admission::QueueCap { .. } => {}
        Admission::Deadline { slack } => {
            if bad(*slack) {
                r.push(Diagnostic::error(
                    "SL-SCN-007",
                    "admission.slack",
                    format!("deadline slack {slack} must be finite and > 0"),
                ));
            }
        }
        Admission::Fair { slack, weights } => {
            if bad(*slack) {
                r.push(Diagnostic::error(
                    "SL-SCN-007",
                    "admission.slack",
                    format!("fair slack {slack} must be finite and > 0"),
                ));
            }
            for (task, w) in weights {
                if bad(*w) {
                    r.push(Diagnostic::error(
                        "SL-SCN-007",
                        format!("admission.weights.{task}"),
                        format!("fair-share weight {w} must be finite and > 0"),
                    ));
                }
            }
        }
        Admission::Predictive { horizon_ms, headroom } => {
            if bad(*horizon_ms) {
                r.push(Diagnostic::error(
                    "SL-SCN-007",
                    "admission.horizon_ms",
                    format!("predictive horizon_ms {horizon_ms} must be finite and > 0"),
                ));
            }
            if bad(*headroom) {
                r.push(Diagnostic::error(
                    "SL-SCN-007",
                    "admission.headroom",
                    format!("predictive headroom {headroom} must be finite and > 0"),
                ));
            }
        }
    }
}

fn lint_dispatch(sc: &Scenario, r: &mut Report) {
    if sc.dispatch.max_batch == 0 {
        r.push(Diagnostic::warn(
            "SL-SCN-010",
            "dispatch.max_batch",
            "max_batch == 0 behaves as 1 (the take rule clamps): say 1 if you mean no batching",
        ));
    }
    if sc.dispatch.is_batching() && sc.dispatch.min_queue == 0 {
        r.push(Diagnostic::warn(
            "SL-SCN-010",
            "dispatch.min_queue",
            "min_queue == 0 behaves as 1: coalescing still needs a waiting query",
        ));
    }
    if sc.sharding.shards == 0 {
        r.push(Diagnostic::warn(
            "SL-SCN-010",
            "sharding.shards",
            "shards == 0 is clamped to 1: say 1 if you mean a single server",
        ));
    }
}

fn lint_sharding_vs_tasks(sharding: &Sharding, tasks: &[String], r: &mut Report) {
    let n = sharding.shards.max(1);
    if let ShardAssignment::Explicit(map) = &sharding.assignment {
        for (task, &shard) in map {
            if !tasks.iter().any(|t| t == task) {
                r.push(Diagnostic::error(
                    "SL-SCN-008",
                    format!("sharding.map.{task}"),
                    format!("sharding map names task {task:?} not in the scenario"),
                ));
            }
            if shard >= n {
                r.push(Diagnostic::error(
                    "SL-SCN-009",
                    format!("sharding.map.{task}"),
                    format!("shard index {shard} out of range for {n} shard(s)"),
                ));
            }
        }
    }
}

// ---- fault-lab profile checks (`SL-SCN-014..017`) --------------------

fn lint_faults(sc: &Scenario, r: &mut Report) {
    lint_fault_shapes(&sc.faults, r);
    lint_fault_shards(&sc.faults, &sc.sharding, r);
    // A crash window that opens at or past the arrival horizon never
    // fires: arrivals stop before it, so the run silently ignores it.
    let horizon = match &sc.arrival {
        Arrival::PoissonOpenLoop { horizon_ms, .. } | Arrival::Bursty { horizon_ms, .. } => {
            Some(*horizon_ms)
        }
        _ => None,
    };
    if let Some(h) = horizon {
        for (i, w) in sc.faults.crashes.iter().enumerate() {
            if w.start_ms.is_finite() && h.is_finite() && w.start_ms >= h {
                r.push(Diagnostic::warn(
                    "SL-SCN-014",
                    format!("faults.crashes[{i}]"),
                    format!(
                        "crash window opens at {} ms, at or past the {h} ms arrival \
                         horizon: no arrival can ever hit it",
                        w.start_ms
                    ),
                ));
            }
        }
    }
}

/// Shape checks that need no sharding context: window bounds, ramp and
/// throttle parameters, link-matrix geometry.
fn lint_fault_shapes(faults: &FaultProfile, r: &mut Report) {
    for (i, w) in faults.crashes.iter().enumerate() {
        if !w.start_ms.is_finite() || !w.end_ms.is_finite() || w.start_ms < 0.0 {
            r.push(Diagnostic::error(
                "SL-SCN-014",
                format!("faults.crashes[{i}]"),
                format!(
                    "crash window [{}, {}) must have finite, non-negative bounds",
                    w.start_ms, w.end_ms
                ),
            ));
        } else if w.end_ms <= w.start_ms {
            r.push(Diagnostic::error(
                "SL-SCN-014",
                format!("faults.crashes[{i}]"),
                format!(
                    "crash window [{}, {}) is empty: end must exceed start",
                    w.start_ms, w.end_ms
                ),
            ));
        }
    }
    for (i, d) in faults.degradations.iter().enumerate() {
        if !d.factor.is_finite() || d.factor <= 0.0 {
            r.push(Diagnostic::error(
                "SL-SCN-015",
                format!("faults.degradations[{i}]"),
                format!("degradation factor {} must be finite and > 0", d.factor),
            ));
        }
        if !d.start_ms.is_finite()
            || d.start_ms < 0.0
            || !d.ramp_ms.is_finite()
            || d.ramp_ms < 0.0
        {
            r.push(Diagnostic::error(
                "SL-SCN-015",
                format!("faults.degradations[{i}]"),
                format!(
                    "degradation start {} ms / ramp {} ms must be finite and ≥ 0",
                    d.start_ms, d.ramp_ms
                ),
            ));
        }
    }
    if let Some(curve) = &faults.throttle {
        let mut prev: Option<f64> = None;
        for (i, s) in curve.steps.iter().enumerate() {
            if !s.factor.is_finite() || s.factor <= 0.0 {
                r.push(Diagnostic::error(
                    "SL-SCN-015",
                    format!("faults.throttle.steps[{i}]"),
                    format!("throttle factor {} must be finite and > 0", s.factor),
                ));
            }
            if !s.busy_ms.is_finite() || s.busy_ms < 0.0 {
                r.push(Diagnostic::error(
                    "SL-SCN-015",
                    format!("faults.throttle.steps[{i}]"),
                    format!("throttle step busy_ms {} must be finite and ≥ 0", s.busy_ms),
                ));
            } else {
                if let Some(p) = prev {
                    if s.busy_ms <= p {
                        r.push(Diagnostic::error(
                            "SL-SCN-015",
                            format!("faults.throttle.steps[{i}]"),
                            format!(
                                "throttle steps must be strictly increasing in busy_ms \
                                 ({} after {p}): factor lookup is a sorted scan",
                                s.busy_ms
                            ),
                        ));
                    }
                }
                prev = Some(s.busy_ms);
            }
        }
    }
    if let Some(links) = &faults.links {
        let n = links.transfer_ms.len();
        for (i, row) in links.transfer_ms.iter().enumerate() {
            if row.len() != n {
                r.push(Diagnostic::error(
                    "SL-SCN-016",
                    format!("faults.links[{i}]"),
                    format!(
                        "link matrix must be square: row {i} has {} entries, expected {n}",
                        row.len()
                    ),
                ));
                continue;
            }
            for (j, &c) in row.iter().enumerate() {
                if !c.is_finite() || c < 0.0 {
                    r.push(Diagnostic::error(
                        "SL-SCN-016",
                        format!("faults.links[{i}][{j}]"),
                        format!("link cost {c} must be finite and ≥ 0"),
                    ));
                } else if i == j && c != 0.0 {
                    r.push(Diagnostic::error(
                        "SL-SCN-016",
                        format!("faults.links[{i}][{j}]"),
                        format!("self-link cost must be 0, got {c}: a shard does not pay to reach itself"),
                    ));
                }
            }
        }
        for i in 0..n {
            for j in (i + 1)..n {
                let fwd = links.transfer_ms.get(i).and_then(|row| row.get(j));
                let rev = links.transfer_ms.get(j).and_then(|row| row.get(i));
                if let (Some(&a), Some(&b)) = (fwd, rev) {
                    if a.is_finite() && b.is_finite() && a != b {
                        r.push(Diagnostic::error(
                            "SL-SCN-016",
                            format!("faults.links[{i}][{j}]"),
                            format!("link matrix must be symmetric: cost {a} ≠ reverse cost {b}"),
                        ));
                    }
                }
            }
        }
    }
}

/// Every fault entry must name a shard the deployment actually has, and
/// a link matrix must be sized to the shard count.
fn lint_fault_shards(faults: &FaultProfile, sharding: &Sharding, r: &mut Report) {
    let n = sharding.shards.max(1);
    for (i, w) in faults.crashes.iter().enumerate() {
        if w.shard >= n {
            r.push(Diagnostic::error(
                "SL-SCN-017",
                format!("faults.crashes[{i}]"),
                format!("crash window names shard {} but the deployment has {n} shard(s)", w.shard),
            ));
        }
    }
    for (i, d) in faults.degradations.iter().enumerate() {
        if d.shard >= n {
            r.push(Diagnostic::error(
                "SL-SCN-017",
                format!("faults.degradations[{i}]"),
                format!("degradation names shard {} but the deployment has {n} shard(s)", d.shard),
            ));
        }
    }
    for (i, e) in faults.expects.iter().enumerate() {
        if let Expect::RecoveryWithin { shard, .. } = e {
            if *shard >= n {
                r.push(Diagnostic::error(
                    "SL-SCN-017",
                    format!("faults.expects[{i}]"),
                    format!(
                        "recovery_within names shard {shard} but the deployment has {n} shard(s)"
                    ),
                ));
            }
        }
    }
    if let Some(links) = &faults.links {
        if links.transfer_ms.len() != n {
            r.push(Diagnostic::error(
                "SL-SCN-016",
                "faults.links",
                format!(
                    "link matrix has {} row(s) but the deployment has {n} shard(s)",
                    links.transfer_ms.len()
                ),
            ));
        }
    }
}

// ---- group 2: cross-layer consistency --------------------------------

fn lint_cross_layer(sc: &Scenario, r: &mut Report) {
    let p = &sc.planner;
    let online = p.replan || p.steal;
    if p.predictive && (!p.horizon_ms.is_finite() || p.horizon_ms <= 0.0) {
        r.push(Diagnostic::error(
            "SL-XLY-001",
            "planner.horizon_ms",
            format!(
                "predictive triggers need a positive forecast horizon, got {}",
                p.horizon_ms
            ),
        ));
    }
    if sc.sharding.shards < 2 {
        if p.steal {
            r.push(Diagnostic::warn(
                "SL-XLY-002",
                "planner.steal",
                "work stealing needs ≥ 2 shards: with one server there is nobody to steal from",
            ));
        }
        if p.warm_migrate {
            r.push(Diagnostic::warn(
                "SL-XLY-002",
                "planner.warm_migrate",
                "warm migration needs ≥ 2 shards: there is no other pool to carry blobs to",
            ));
        }
        if p.replan {
            r.push(Diagnostic::warn(
                "SL-XLY-003",
                "planner.replan",
                "online re-planning acts on a sharded run: with shards < 2 the knob never fires",
            ));
        }
    }
    if online && (!p.saturation_slack.is_finite() || p.saturation_slack <= 0.0) {
        r.push(Diagnostic::error(
            "SL-XLY-004",
            "planner.saturation_slack",
            format!(
                "online paths trigger on saturation_slack × mean SLO latency; \
                 {} would saturate immediately (or never)",
                p.saturation_slack
            ),
        ));
    }
    if p.warm_migrate && !online {
        r.push(Diagnostic::warn(
            "SL-XLY-005",
            "planner.warm_migrate",
            "warm_migrate only acts on the replan/steal adoption paths: alone it is a silent no-op",
        ));
    }
    if p.replan && p.max_migrations == 0 {
        r.push(Diagnostic::warn(
            "SL-XLY-006",
            "planner.max_migrations",
            "replan with max_migrations == 0 evaluates migrations it may never apply",
        ));
    }
    if p.batch_aware && sc.dispatch.max_batch <= 1 {
        r.push(Diagnostic::info(
            "SL-XLY-007",
            "planner.batch_aware",
            "batch-aware planning with max_batch ≤ 1 plans at the batch-1 operating point anyway",
        ));
    }
    if matches!(sc.arrival, Arrival::ClosedLoop { .. }) && sc.admission != Admission::Always {
        r.push(Diagnostic::info(
            "SL-XLY-008",
            "admission",
            "closed loops are self-clocking and never build backlog: this admission policy never sheds",
        ));
    }
}

// ---- online synthesis checks (`SL-STI-*`) ----------------------------

/// Stitch-synthesis configuration checks: the `planner.synthesize`
/// action only fires on the online drive, under the same saturation
/// trigger as replan/steal, and scores candidates at the live batch
/// operating point — configurations that contradict any of that are
/// flagged here.
fn lint_stitching(sc: &Scenario, r: &mut Report) {
    let p = &sc.planner;
    if !p.synthesize {
        return;
    }
    if !p.batch_aware {
        r.push(Diagnostic::warn(
            "SL-STI-001",
            "planner.synthesize",
            "online synthesis scores candidates at the live batch operating point; \
             without batch_aware the enumerated plan prices latency at batch 1 and \
             the two disagree on what is feasible",
        ));
    }
    if matches!(sc.arrival, Arrival::ClosedLoop { .. }) {
        r.push(Diagnostic::warn(
            "SL-STI-002",
            "planner.synthesize",
            "closed loops are self-clocking and route to the static drive: the \
             synthesis action never fires there",
        ));
    }
    if !p.saturation_slack.is_finite() || p.saturation_slack <= 0.0 {
        r.push(Diagnostic::error(
            "SL-STI-003",
            "planner.saturation_slack",
            format!(
                "synthesis triggers on saturation_slack × mean SLO latency; {} \
                 would trigger on every batch (or never)",
                p.saturation_slack
            ),
        ));
    }
}

/// SL-XLY-010: tracing with request-event retention off. The trace
/// itself is complete either way (the sink is independent of the
/// retained `RequestOutcome` log), but the invariant verifier's
/// trace-consistency pass cross-checks trace spans against that log —
/// without it, a `--verify` replay cannot vouch for the trace. This is
/// a run-mode gate (CLI flags, not scenario fields), so it lives
/// outside [`lint_scenario`].
pub fn trace_mode_gate(trace: bool, record_events: bool) -> Report {
    let mut r = Report::new();
    if trace && !record_events {
        r.push(Diagnostic::warn(
            "SL-XLY-010",
            "serve --trace",
            "tracing without event retention: pass --verify to retain request events \
             and cross-check the trace against them",
        ));
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{Dispatch, PlannerConfig};
    use crate::workload::Query;

    fn slos() -> BTreeMap<String, Slo> {
        BTreeMap::from([
            ("a".to_string(), Slo { min_accuracy: 0.8, max_latency_ms: 40.0 }),
            ("b".to_string(), Slo { min_accuracy: 0.9, max_latency_ms: 25.0 }),
        ])
    }

    fn tasks() -> Vec<String> {
        vec!["a".to_string(), "b".to_string()]
    }

    fn codes(r: &Report) -> Vec<&'static str> {
        r.diagnostics.iter().map(|d| d.code).collect()
    }

    #[test]
    fn clean_scenario_is_clean() {
        let sc = Scenario::closed_loop(&tasks(), slos());
        let r = lint_scenario(&sc);
        assert!(r.is_empty(), "{}", r.render_text());
    }

    #[test]
    fn duplicate_and_missing_slo() {
        let mut sc = Scenario::closed_loop(&tasks(), slos());
        sc.tasks.push("a".to_string());
        sc.schedule[0].remove("b");
        let r = lint_scenario(&sc);
        assert!(codes(&r).contains(&"SL-SCN-002"), "{}", r.render_text());
        assert!(codes(&r).contains(&"SL-SCN-004"), "{}", r.render_text());
        assert!(r.has_errors());
    }

    #[test]
    fn universe_must_cover_schedule() {
        let sc = Scenario::closed_loop(&tasks(), slos())
            .with_universe(vec![Slo { min_accuracy: 0.8, max_latency_ms: 40.0 }]);
        let r = lint_scenario(&sc);
        // b's SLO (0.9, 25 ms) is served but absent from Ψ.
        assert!(codes(&r).contains(&"SL-SCN-005"), "{}", r.render_text());
        // A covering universe is clean.
        let ok = Scenario::closed_loop(&tasks(), slos()).with_universe(vec![
            Slo { min_accuracy: 0.8, max_latency_ms: 40.0 },
            Slo { min_accuracy: 0.9, max_latency_ms: 25.0 },
        ]);
        assert!(lint_scenario(&ok).is_empty());
    }

    #[test]
    fn nonpositive_rates_and_admission_ranges() {
        let sc = Scenario::poisson(&tasks(), slos(), 0.0, -5.0)
            .with_admission(Admission::Predictive { horizon_ms: -1.0, headroom: 0.0 });
        let r = lint_scenario(&sc);
        let c = codes(&r);
        assert_eq!(c.iter().filter(|&&x| x == "SL-SCN-006").count(), 2, "{}", r.render_text());
        assert_eq!(c.iter().filter(|&&x| x == "SL-SCN-007").count(), 2, "{}", r.render_text());
    }

    #[test]
    fn sharding_map_unknown_task_and_range() {
        let sc = Scenario::closed_loop(&tasks(), slos()).with_sharding(Sharding {
            shards: 2,
            assignment: ShardAssignment::Explicit(BTreeMap::from([
                ("a".to_string(), 0),
                ("ghost".to_string(), 1),
                ("b".to_string(), 7),
            ])),
        });
        let r = lint_scenario(&sc);
        let c = codes(&r);
        assert!(c.contains(&"SL-SCN-008"), "{}", r.render_text());
        assert!(c.contains(&"SL-SCN-009"), "{}", r.render_text());
    }

    #[test]
    fn synthesis_lints_flag_contradictory_configs() {
        // synthesize without batch_aware: plan and synthesis disagree
        // on the operating point (warn), and a closed loop never fires
        // the action at all (warn). Neither blocks.
        let sc = Scenario::closed_loop(&tasks(), slos()).with_planner(PlannerConfig {
            synthesize: true,
            ..PlannerConfig::default()
        });
        let r = lint_scenario(&sc);
        let c = codes(&r);
        assert!(c.contains(&"SL-STI-001"), "{}", r.render_text());
        assert!(c.contains(&"SL-STI-002"), "{}", r.render_text());
        assert!(!r.has_errors(), "{}", r.render_text());

        // Degenerate saturation slack makes the trigger meaningless:
        // that one is an Error even without replan/steal (SL-XLY-004
        // does not cover the synthesize-only path).
        let sc = Scenario::poisson(&tasks(), slos(), 10.0, 1000.0).with_planner(
            PlannerConfig {
                batch_aware: true,
                synthesize: true,
                saturation_slack: 0.0,
                ..PlannerConfig::default()
            },
        );
        let r = lint_scenario(&sc);
        assert!(codes(&r).contains(&"SL-STI-003"), "{}", r.render_text());
        assert!(r.has_errors());

        // A sane synthesis config is lint-clean.
        let sc = Scenario::poisson(&tasks(), slos(), 10.0, 1000.0).with_planner(
            PlannerConfig {
                batch_aware: true,
                synthesize: true,
                ..PlannerConfig::default()
            },
        );
        let r = lint_scenario(&sc);
        assert!(
            !codes(&r).iter().any(|c| c.starts_with("SL-STI")),
            "{}",
            r.render_text()
        );
    }

    #[test]
    fn footguns_warn_but_do_not_block() {
        let sc = Scenario::poisson(&tasks(), slos(), 10.0, 1000.0)
            .with_dispatch(Dispatch { max_batch: 0, min_queue: 2 });
        let r = lint_scenario(&sc);
        assert!(codes(&r).contains(&"SL-SCN-010"));
        assert!(!r.has_errors(), "{}", r.render_text());
        assert!(r.fail_on_errors("scenario").is_ok());
    }

    #[test]
    fn trace_checks() {
        let sc = Scenario::trace(
            &tasks(),
            slos(),
            vec![
                Query { task: "a".into(), arrival_ms: 5.0, id: 0 },
                Query { task: "ghost".into(), arrival_ms: 1.0, id: 1 },
                Query { task: "a".into(), arrival_ms: 2.0, id: 2 },
            ],
        );
        let r = lint_scenario(&sc);
        let c = codes(&r);
        // Unknown task errors; the time-travel arrival only warns.
        assert_eq!(c.iter().filter(|&&x| x == "SL-SCN-011").count(), 2, "{}", r.render_text());
        assert_eq!(r.errors(), 1, "{}", r.render_text());
    }

    #[test]
    fn cross_layer_lints() {
        // Online knobs on one shard: warnings, not errors.
        let sc = Scenario::poisson(&tasks(), slos(), 10.0, 1000.0)
            .with_planner(PlannerConfig::online());
        let r = lint_scenario(&sc);
        let c = codes(&r);
        assert!(c.contains(&"SL-XLY-002"), "{}", r.render_text());
        assert!(c.contains(&"SL-XLY-003"), "{}", r.render_text());
        assert!(!r.has_errors());

        // Predictive without a horizon is an error.
        let mut pc = PlannerConfig::predictive();
        pc.horizon_ms = 0.0;
        let sc = Scenario::poisson(&tasks(), slos(), 10.0, 1000.0)
            .with_sharding(Sharding::hash(2))
            .with_planner(pc);
        assert!(codes(&lint_scenario(&sc)).contains(&"SL-XLY-001"));

        // Lone warm_migrate is a silent no-op.
        let mut pc = PlannerConfig::default();
        pc.warm_migrate = true;
        let sc = Scenario::poisson(&tasks(), slos(), 10.0, 1000.0)
            .with_sharding(Sharding::hash(2))
            .with_planner(pc);
        assert!(codes(&lint_scenario(&sc)).contains(&"SL-XLY-005"));

        // Closed loop + shedding admission: advisory note only.
        let sc = Scenario::closed_loop(&tasks(), slos())
            .with_admission(Admission::Deadline { slack: 2.0 });
        let r = lint_scenario(&sc);
        assert!(codes(&r).contains(&"SL-XLY-008"));
        assert_eq!(r.errors(), 0);
    }

    #[test]
    fn build_gate_rejects_bad_explicit_maps() {
        let (_zoo, _lm, profiles) = crate::fixtures::tiny();
        let inert = FaultProfile::default();
        let good = Sharding::explicit(BTreeMap::from([("tiny".to_string(), 0)]), 2);
        assert!(build_gate(&good, &profiles, &inert).fail_on_errors("sharding").is_ok());
        let unknown = Sharding::explicit(BTreeMap::from([("ghost".to_string(), 0)]), 2);
        assert!(build_gate(&unknown, &profiles, &inert).has_errors());
        let out_of_range = Sharding::explicit(BTreeMap::from([("tiny".to_string(), 5)]), 2);
        assert!(build_gate(&out_of_range, &profiles, &inert).has_errors());
    }

    #[test]
    fn fault_lints_catch_malformed_profiles() {
        use crate::scenario::{CrashWindow, Degradation, RejoinMode, ThrottleCurve, ThrottleStep};
        let base = || Scenario::poisson(&tasks(), slos(), 10.0, 1000.0);

        // Empty crash window (end ≤ start) is an error.
        let sc = base().with_faults(FaultProfile {
            crashes: vec![CrashWindow {
                shard: 0,
                start_ms: 50.0,
                end_ms: 50.0,
                rejoin: RejoinMode::Cold,
            }],
            ..FaultProfile::default()
        });
        let r = lint_scenario(&sc);
        assert!(codes(&r).contains(&"SL-SCN-014"), "{}", r.render_text());
        assert!(r.has_errors());

        // A window that opens past the arrival horizon only warns.
        let sc = base().with_faults(FaultProfile {
            crashes: vec![CrashWindow {
                shard: 0,
                start_ms: 2000.0,
                end_ms: 2500.0,
                rejoin: RejoinMode::Cold,
            }],
            ..FaultProfile::default()
        });
        let r = lint_scenario(&sc);
        assert!(codes(&r).contains(&"SL-SCN-014"), "{}", r.render_text());
        assert!(!r.has_errors(), "{}", r.render_text());

        // Nonpositive degradation factor and unsorted throttle steps.
        let sc = base().with_faults(FaultProfile {
            degradations: vec![Degradation {
                shard: 0,
                start_ms: 0.0,
                ramp_ms: 100.0,
                factor: 0.0,
            }],
            throttle: Some(ThrottleCurve {
                steps: vec![
                    ThrottleStep { busy_ms: 50.0, factor: 1.5 },
                    ThrottleStep { busy_ms: 10.0, factor: 2.0 },
                ],
            }),
            ..FaultProfile::default()
        });
        let r = lint_scenario(&sc);
        assert_eq!(
            codes(&r).iter().filter(|&&x| x == "SL-SCN-015").count(),
            2,
            "{}",
            r.render_text()
        );
    }

    #[test]
    fn fault_lints_catch_bad_link_matrices_and_shard_ranges() {
        use crate::scenario::{CrashWindow, LinkMatrix, RejoinMode};
        let two_shards = || {
            Scenario::poisson(&tasks(), slos(), 10.0, 1000.0).with_sharding(Sharding::hash(2))
        };

        // Asymmetric + self-loop cost: both SL-SCN-016 errors.
        let sc = two_shards().with_faults(FaultProfile {
            links: Some(LinkMatrix {
                transfer_ms: vec![vec![0.0, 3.0], vec![5.0, 1.0]],
            }),
            ..FaultProfile::default()
        });
        let r = lint_scenario(&sc);
        assert_eq!(
            codes(&r).iter().filter(|&&x| x == "SL-SCN-016").count(),
            2,
            "{}",
            r.render_text()
        );

        // Link matrix sized for 3 shards on a 2-shard deployment.
        let sc = two_shards().with_faults(FaultProfile {
            links: Some(LinkMatrix {
                transfer_ms: vec![vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]],
            }),
            ..FaultProfile::default()
        });
        assert!(codes(&lint_scenario(&sc)).contains(&"SL-SCN-016"));

        // Crash window and recovery expectation naming a ghost shard.
        let sc = two_shards().with_faults(FaultProfile {
            crashes: vec![CrashWindow {
                shard: 5,
                start_ms: 10.0,
                end_ms: 20.0,
                rejoin: RejoinMode::Warm,
            }],
            expects: vec![Expect::RecoveryWithin { shard: 9, ms: 50.0 }],
            ..FaultProfile::default()
        });
        let r = lint_scenario(&sc);
        assert_eq!(
            codes(&r).iter().filter(|&&x| x == "SL-SCN-017").count(),
            2,
            "{}",
            r.render_text()
        );
    }

    #[test]
    fn build_gate_rejects_bad_fault_profiles() {
        use crate::scenario::{CrashWindow, RejoinMode};
        let (_zoo, _lm, profiles) = crate::fixtures::tiny();
        let sharding = Sharding::hash(2);
        let bad = FaultProfile {
            crashes: vec![CrashWindow {
                shard: 7,
                start_ms: 0.0,
                end_ms: 10.0,
                rejoin: RejoinMode::Cold,
            }],
            ..FaultProfile::default()
        };
        let r = build_gate(&sharding, &profiles, &bad);
        assert!(codes(&r).contains(&"SL-SCN-017"), "{}", r.render_text());
        // A well-formed profile on a shard that exists passes the gate.
        let ok = FaultProfile {
            crashes: vec![CrashWindow {
                shard: 1,
                start_ms: 0.0,
                end_ms: 10.0,
                rejoin: RejoinMode::Warm,
            }],
            ..FaultProfile::default()
        };
        assert!(build_gate(&sharding, &profiles, &ok).fail_on_errors("faults").is_ok());
    }

    #[test]
    fn session_gate_matches_engine_contract() {
        let (_zoo, _lm, profiles) = crate::fixtures::tiny();
        let sc = Scenario::closed_loop(&["tiny".to_string()], BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 0.5, max_latency_ms: 1e9 },
        )]));
        assert!(session_gate(&sc, 0, &profiles).is_empty());
        // Unknown task, missing phase SLO, duplicate: all errors.
        let bad = sc.clone().with_tasks(&["tiny".to_string(), "ghost".to_string()]);
        let r = session_gate(&bad, 0, &profiles);
        assert!(codes(&r).contains(&"SL-FEA-001"));
        assert!(codes(&r).contains(&"SL-SCN-004"));
        let dup = sc.with_tasks(&["tiny".to_string(), "tiny".to_string()]);
        assert!(codes(&session_gate(&dup, 0, &profiles)).contains(&"SL-SCN-002"));
    }

    #[test]
    fn trace_without_retention_warns() {
        let r = trace_mode_gate(true, false);
        assert!(codes(&r).contains(&"SL-XLY-010"), "{}", r.render_text());
        assert!(!r.has_errors(), "SL-XLY-010 is advisory, never blocking");
        assert!(trace_mode_gate(true, true).is_empty());
        assert!(trace_mode_gate(false, false).is_empty());
        assert!(trace_mode_gate(false, true).is_empty());
    }
}
