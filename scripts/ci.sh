#!/usr/bin/env bash
# Tier-1 verification: build, test, and (when available) format check.
# Run from anywhere; operates on the repository root.
set -euo pipefail

cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Bench targets are plain main()s (harness = false): running them under
# `cargo test` compile-checks every bench and executes it once — each
# falls back to the synthetic fixture zoo (or exits cleanly) when
# artifacts/ is absent, so this stays fast and hermetic.
echo "== cargo test -q --benches =="
cargo test -q --benches

# Rustdoc must stay warning-free (broken intra-doc links, bad code
# fences); doc-examples themselves run as doc-tests under `cargo test`.
echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

# Lints across every target (tests, benches, examples). clippy is
# optional in minimal toolchains; when installed, warnings are errors.
if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy --all-targets (-D warnings) =="
    cargo clippy --all-targets --quiet -- -D warnings
else
    echo "== cargo clippy skipped (clippy not installed) =="
fi

# rustfmt is optional in minimal toolchains; tolerate its absence but
# fail on real formatting drift when it is installed.
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --all -- --check
else
    echo "== cargo fmt --check skipped (rustfmt not installed) =="
fi

echo "CI OK"
