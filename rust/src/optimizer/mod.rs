//! Sparsity-Aware Optimizer plan types (paper §3.3, Algorithm 1).
//!
//! The algorithm itself lives in `crate::planner::algo` (batch-aware,
//! pruned, with an explicit `CostModel`); this module keeps only the
//! plan *types* it returns. The long-deprecated free-function shims
//! (`feasible_set` / `optimize` / `optimize_pure_only` at the unit
//! cost model) are gone — call `planner::algo` with
//! `CostModel::unit()` for the batch-1 behavior. The Algorithm 1 math
//! notes moved to DESIGN.md §"Algorithm 1".

use std::collections::BTreeMap;

use crate::profiler::TaskProfile;
use crate::soc::Processor;
use crate::stitching::Composition;

/// The filtered candidate set Θᵗ for one task.
#[derive(Clone, Debug, Default)]
pub struct CandidateSet {
    /// Stitched indices satisfying the SLO (accuracy via the estimator,
    /// latency achievable under at least one order in Ω).
    pub indices: Vec<usize>,
}

impl CandidateSet {
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }
}

/// The optimizer's decision for a whole SLO configuration.
#[derive(Clone, Debug)]
pub struct Plan {
    /// p⃗* — the global placement order.
    pub order: Vec<Processor>,
    /// Per task: chosen stitched index and its estimated latency, or
    /// `None` when Θᵗ was empty (an unavoidable SLO violation).
    pub selections: BTreeMap<String, Option<Selection>>,
    /// L(p⃗*) — mean best latency across tasks (selected ones).
    pub mean_latency_ms: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct Selection {
    pub stitched_index: usize,
    pub latency_ms: f64,
    pub accuracy: f64,
}

impl Plan {
    pub fn composition_for(&self, profile: &TaskProfile) -> Option<Composition> {
        self.selections
            .get(&profile.task)
            .and_then(|s| s.as_ref())
            .map(|s| profile.space.composition(s.stitched_index))
    }

    /// Number of tasks with no feasible variant.
    pub fn infeasible_tasks(&self) -> usize {
        self.selections.values().filter(|s| s.is_none()).count()
    }
}
