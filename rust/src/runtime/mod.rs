//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client): `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile` →
//! `execute_b`. One compiled executable per (task, subgraph, kernel-path,
//! batch); per-variant weights are uploaded once as device buffers and
//! cached; stitched-variant inference chains stage outputs as device
//! buffers with no host round-trips (the hot path).
//!
//! Python never appears here — artifacts are self-contained HLO text +
//! weight blobs produced by `make artifacts`.
//!
//! **Feature gate:** the real backend compiles only with
//! `--features xla` (which needs the `xla` crate — see rust/Cargo.toml).
//! Without it this module exposes the same surface as a stub whose
//! `Runtime::new()` returns an error, so the simulation side — scenario
//! server, coordinator, experiments with `--synthetic` — builds and
//! runs without any PJRT plugin.

use crate::zoo::KernelPath;

/// Key for the executable cache.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ExeKey {
    pub task: String,
    pub subgraph: usize,
    pub path: KernelPath,
    pub batch: usize,
}

/// Timing of one chained stitched-variant inference.
#[derive(Clone, Debug, Default)]
pub struct ChainTiming {
    /// Per-stage execute wall time (ms).
    pub stage_ms: Vec<f64>,
    /// Total including activation hand-off.
    pub total_ms: f64,
}

#[cfg(feature = "xla")]
mod pjrt_impl {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};
    use std::time::Instant;

    use anyhow::{bail, Context, Result};

    use crate::soc::BlobId;
    use crate::zoo::{DType, KernelPath, SubgraphWeights, Zoo};

    use super::{ChainTiming, ExeKey};

    /// A compiled subgraph executable plus its interface metadata.
    pub struct Executable {
        pub key: ExeKey,
        pub exe: xla::PjRtLoadedExecutable,
        pub input_dim: usize,
        pub output_dim: usize,
        /// Wall-clock cost of parsing + compiling the HLO (Fig. 5a "compile").
        pub compile_ms: f64,
    }

    /// The process-wide PJRT engine.
    pub struct Runtime {
        client: xla::PjRtClient,
        exes: Mutex<HashMap<ExeKey, Arc<Executable>>>,
        weights: Mutex<HashMap<BlobId, Arc<Vec<xla::PjRtBuffer>>>>,
    }

    impl Runtime {
        /// Create a PJRT CPU client. One per process is plenty — executables
        /// and buffers are shared through the caches.
        pub fn new() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self {
                client,
                exes: Mutex::new(HashMap::new()),
                weights: Mutex::new(HashMap::new()),
            })
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch) the executable for (task, sg, path, batch).
        pub fn executable(
            &self,
            zoo: &Zoo,
            task: &str,
            sg: usize,
            path: KernelPath,
            batch: usize,
        ) -> Result<Arc<Executable>> {
            let key = ExeKey { task: task.to_string(), subgraph: sg, path, batch };
            if let Some(exe) = self.exes.lock().unwrap().get(&key) {
                return Ok(Arc::clone(exe));
            }
            let tz = zoo.task(task)?;
            let art = tz.hlo_for(sg, path, batch)?;
            let t0 = Instant::now();
            let file = art.file.to_str().context("non-utf8 artifact path")?;
            let proto = xla::HloModuleProto::from_text_file(file)
                .with_context(|| format!("parsing HLO text {file}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {file}"))?;
            let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
            let exe = Arc::new(Executable {
                key: key.clone(),
                exe,
                input_dim: art.input_dim,
                output_dim: art.output_dim,
                compile_ms,
            });
            self.exes.lock().unwrap().insert(key, Arc::clone(&exe));
            Ok(exe)
        }

        /// Number of compiled executables resident.
        pub fn n_executables(&self) -> usize {
            self.exes.lock().unwrap().len()
        }

        /// Upload (or fetch) the device buffers for one weight blob.
        /// Returns the buffers and the upload wall time in ms (0 on cache hit).
        pub fn weight_buffers(
            &self,
            zoo: &Zoo,
            task: &str,
            variant: usize,
            sg: usize,
        ) -> Result<(Arc<Vec<xla::PjRtBuffer>>, f64)> {
            let id = BlobId::new(task, variant, sg);
            if let Some(bufs) = self.weights.lock().unwrap().get(&id) {
                return Ok((Arc::clone(bufs), 0.0));
            }
            let tz = zoo.task(task)?;
            let sw: &SubgraphWeights = &tz.variants[variant].subgraphs[sg];
            let t0 = Instant::now();
            let tensors = zoo.load_weights(sw)?;
            let mut bufs = Vec::with_capacity(tensors.len());
            for (spec, bytes) in sw.params.iter().zip(&tensors) {
                // NOTE: two upstream traps here (xla 0.1.6):
                //  * `buffer_from_host_raw_bytes` passes the ElementType
                //    discriminant where PJRT expects a PrimitiveType,
                //    corrupting the dtype;
                //  * `buffer_from_host_literal` is asynchronous
                //    (BufferFromHostLiteral) — dropping the literal before
                //    the transfer lands is a use-after-free.
                // `buffer_from_host_buffer` copies synchronously
                // (kImmutableOnlyDuringCall) with the correct dtype.
                let buf = match spec.dtype {
                    DType::F32 => {
                        let mut host = vec![0f32; spec.elems()];
                        for (i, c) in bytes.chunks_exact(4).enumerate() {
                            host[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                        }
                        self.client.buffer_from_host_buffer(&host, &spec.shape, None)
                    }
                    DType::I8 => {
                        let host: Vec<i8> = bytes.iter().map(|&b| b as i8).collect();
                        self.client.buffer_from_host_buffer(&host, &spec.shape, None)
                    }
                }
                .with_context(|| format!("uploading {}", sw.file.display()))?;
                bufs.push(buf);
            }
            let load_ms = t0.elapsed().as_secs_f64() * 1e3;
            let bufs = Arc::new(bufs);
            self.weights.lock().unwrap().insert(id, Arc::clone(&bufs));
            Ok((bufs, load_ms))
        }

        /// Drop cached weight buffers (the coordinator's eviction hook).
        pub fn evict_weights(&self, id: &BlobId) {
            self.weights.lock().unwrap().remove(id);
        }

        pub fn n_weight_blobs(&self) -> usize {
            self.weights.lock().unwrap().len()
        }

        /// Upload an activation (row-major f32, shape [batch, dim]).
        pub fn activation(&self, data: &[f32], batch: usize, dim: usize) -> Result<xla::PjRtBuffer> {
            if data.len() != batch * dim {
                bail!("activation has {} elems, want {}×{}", data.len(), batch, dim);
            }
            self.client
                .buffer_from_host_buffer(data, &[batch, dim], None)
                .context("uploading activation")
        }

        /// Execute one subgraph on a device-resident activation. The PJRT
        /// executable root is a 1-tuple (XLA wraps results regardless of the
        /// lowering's `return_tuple`), so the returned buffer is the tuple.
        pub fn run_subgraph(
            &self,
            exe: &Executable,
            x: &xla::PjRtBuffer,
            weights: &[xla::PjRtBuffer],
        ) -> Result<xla::PjRtBuffer> {
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + weights.len());
            args.push(x);
            args.extend(weights.iter());
            let mut out = exe.exe.execute_b(&args).context("execute_b")?;
            let mut replicas = out.pop().context("no replica outputs")?;
            replicas.pop().context("no output buffer")
        }

        /// Download a stage's output as the array literal. Handles both
        /// root conventions: plain array (return_tuple=False artifacts — the
        /// fast path) and 1-tuple (legacy lowering).
        fn stage_literal(&self, buf: &xla::PjRtBuffer) -> Result<xla::Literal> {
            let lit = buf.to_literal_sync().context("downloading stage output")?;
            match lit.shape()? {
                xla::Shape::Tuple(_) => lit.to_tuple1().context("untupling stage output"),
                _ => Ok(lit),
            }
        }

        /// Is this buffer directly consumable as the next stage's input?
        fn is_array_buffer(buf: &xla::PjRtBuffer) -> bool {
            matches!(buf.on_device_shape(), Ok(xla::Shape::Array(_)))
        }

        /// Re-upload a stage output as the next stage's input buffer — only
        /// needed for legacy tuple-rooted artifacts (the xla crate exposes no
        /// on-device tuple split). Array-rooted artifacts chain buffers
        /// directly with zero host copies.
        fn stage_handoff(&self, buf: xla::PjRtBuffer) -> Result<xla::PjRtBuffer> {
            if Self::is_array_buffer(&buf) {
                return Ok(buf);
            }
            let lit = self.stage_literal(&buf)?;
            let shape = lit.array_shape().context("stage output shape")?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let host: Vec<f32> = lit.to_vec().context("stage output to_vec")?;
            self.client
                .buffer_from_host_buffer(&host, &dims, None)
                .context("re-uploading activation")
        }

        /// Run a full stitched-variant chain on host data; returns the
        /// logits (host) and per-stage timings.
        pub fn run_chain(
            &self,
            zoo: &Zoo,
            task: &str,
            composition: &[usize],
            batch: usize,
            input: &[f32],
        ) -> Result<(Vec<f32>, ChainTiming)> {
            let tz = zoo.task(task)?;
            if composition.len() != zoo.subgraphs {
                bail!("composition has {} stages, want {}", composition.len(), zoo.subgraphs);
            }
            let t0 = Instant::now();
            let mut timing = ChainTiming::default();
            let mut act = self.activation(input, batch, tz.input_dim)?;
            let stages = composition.len();
            let mut last = None;
            for (sg, &vi) in composition.iter().enumerate() {
                let path = tz.variants[vi].spec.kernel_path;
                let exe = self.executable(zoo, task, sg, path, batch)?;
                let (weights, _) = self.weight_buffers(zoo, task, vi, sg)?;
                let s0 = Instant::now();
                let out = self.run_subgraph(&exe, &act, &weights)?;
                if sg + 1 < stages {
                    act = self.stage_handoff(out)?;
                } else {
                    last = Some(out);
                }
                timing.stage_ms.push(s0.elapsed().as_secs_f64() * 1e3);
            }
            let lit = self.stage_literal(&last.context("empty composition")?)?;
            let out: Vec<f32> = lit.to_vec().context("logits to_vec")?;
            timing.total_ms = t0.elapsed().as_secs_f64() * 1e3;
            Ok((out, timing))
        }

        /// Measure the batch-1 inference latency of one (task, sg, path)
        /// executable: median of `iters` runs on a fixed random activation.
        pub fn measure_subgraph_ms(
            &self,
            zoo: &Zoo,
            task: &str,
            sg: usize,
            path: KernelPath,
            iters: usize,
        ) -> Result<f64> {
            let tz = zoo.task(task)?;
            // Any variant with this kernel path supplies the weights.
            let vi = tz
                .variants
                .iter()
                .position(|v| v.spec.kernel_path == path)
                .with_context(|| format!("no variant with path {} in {task}", path.name()))?;
            let exe = self.executable(zoo, task, sg, path, 1)?;
            let (weights, _) = self.weight_buffers(zoo, task, vi, sg)?;
            let dim = exe.input_dim;
            let input: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.37).sin()).collect();
            let act = self.activation(&input, 1, dim)?;
            // Warmup.
            let out = self.run_subgraph(&exe, &act, &weights)?;
            let _ = self.stage_literal(&out)?;
            let mut samples = Vec::with_capacity(iters);
            for _ in 0..iters.max(1) {
                let t0 = Instant::now();
                let out = self.run_subgraph(&exe, &act, &weights)?;
                let _ = self.stage_literal(&out)?; // force completion
                samples.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            Ok(crate::util::stats::median(&samples))
        }

        /// Classify the eval set through a composition; returns accuracy.
        /// This is the *measured* accuracy path (the paper's profiling runs);
        /// the python-exported oracle is its precomputed equivalent.
        pub fn measure_accuracy(
            &self,
            zoo: &Zoo,
            task: &str,
            composition: &[usize],
        ) -> Result<f64> {
            let tz = zoo.task(task)?;
            let (xs, ys) = zoo.load_eval(task)?;
            let d = tz.input_dim;
            let eval_batch = *zoo
                .batch_sizes
                .iter()
                .filter(|&&b| b > 1)
                .max()
                .context("no eval batch size in manifest")?;
            let n = zoo.n_eval;
            let classes = zoo.n_classes;
            let mut correct = 0usize;
            let mut done = 0usize;
            while done < n {
                let take = eval_batch.min(n - done);
                // Pad the final chunk up to the compiled batch size.
                let mut chunk = vec![0f32; eval_batch * d];
                chunk[..take * d].copy_from_slice(&xs[done * d..(done + take) * d]);
                let (logits, _) = self.run_chain(zoo, task, composition, eval_batch, &chunk)?;
                for r in 0..take {
                    let row = &logits[r * classes..(r + 1) * classes];
                    let pred = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i as u32)
                        .unwrap();
                    if pred == ys[done + r] {
                        correct += 1;
                    }
                }
                done += take;
            }
            Ok(correct as f64 / n as f64)
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt_impl::{Executable, Runtime};

/// PJRT-free stub: identical surface, but [`Runtime::new`] always
/// errors, so no method body can ever run (the `Runtime` type is
/// uninhabited). Everything simulation-side works without it.
#[cfg(not(feature = "xla"))]
mod stub_impl {
    use std::sync::Arc;

    use anyhow::{bail, Result};

    use crate::soc::BlobId;
    use crate::zoo::{KernelPath, Zoo};

    use super::{ChainTiming, ExeKey};

    enum Void {}

    /// Stand-in for a compiled subgraph executable (never constructed).
    pub struct Executable {
        pub key: ExeKey,
        pub input_dim: usize,
        pub output_dim: usize,
        pub compile_ms: f64,
    }

    /// Stand-in for a device weight buffer (never constructed).
    pub struct WeightBuffer;

    /// Uninhabited stand-in for the PJRT engine: constructing it fails,
    /// so the simulation-only build carries no dead execution paths.
    pub struct Runtime {
        void: Void,
    }

    impl Runtime {
        pub fn new() -> Result<Self> {
            bail!(
                "sparseloom was built without the `xla` feature — the real \
                 PJRT runtime is unavailable. Rebuild with `--features xla` \
                 (see rust/Cargo.toml) or use the simulated paths \
                 (--synthetic / scenario server without a runtime)."
            );
        }

        pub fn platform_name(&self) -> String {
            match self.void {}
        }

        pub fn executable(
            &self,
            _zoo: &Zoo,
            _task: &str,
            _sg: usize,
            _path: KernelPath,
            _batch: usize,
        ) -> Result<Arc<Executable>> {
            match self.void {}
        }

        pub fn n_executables(&self) -> usize {
            match self.void {}
        }

        pub fn weight_buffers(
            &self,
            _zoo: &Zoo,
            _task: &str,
            _variant: usize,
            _sg: usize,
        ) -> Result<(Arc<Vec<WeightBuffer>>, f64)> {
            match self.void {}
        }

        pub fn evict_weights(&self, _id: &BlobId) {
            match self.void {}
        }

        pub fn n_weight_blobs(&self) -> usize {
            match self.void {}
        }

        pub fn run_chain(
            &self,
            _zoo: &Zoo,
            _task: &str,
            _composition: &[usize],
            _batch: usize,
            _input: &[f32],
        ) -> Result<(Vec<f32>, ChainTiming)> {
            match self.void {}
        }

        pub fn measure_subgraph_ms(
            &self,
            _zoo: &Zoo,
            _task: &str,
            _sg: usize,
            _path: KernelPath,
            _iters: usize,
        ) -> Result<f64> {
            match self.void {}
        }

        pub fn measure_accuracy(
            &self,
            _zoo: &Zoo,
            _task: &str,
            _composition: &[usize],
        ) -> Result<f64> {
            match self.void {}
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub_impl::{Executable, Runtime, WeightBuffer};

#[cfg(test)]
mod tests {
    // Runtime tests that need real artifacts live in rust/tests/
    // (integration), where `artifacts/` presence is checked. Unit tests
    // here cover the key plumbing that needs no PJRT session.
    use super::*;
    use crate::zoo::KernelPath;

    #[test]
    fn exe_key_equality() {
        let a = ExeKey { task: "t".into(), subgraph: 1, path: KernelPath::Dense, batch: 1 };
        let b = a.clone();
        assert_eq!(a, b);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = Runtime::new().err().expect("stub must not construct");
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
