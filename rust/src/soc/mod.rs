//! Simulated heterogeneous edge SoC substrate.
//!
//! The paper's testbeds (Intel Ultra 7/5 with CPU+GPU+NPU, Jetson AGX
//! Orin with CPU+GPU) are hardware-gated; this module is the calibrated
//! stand-in (DESIGN.md §Substitutions): platform profiles project
//! *measured* PJRT-CPU latencies onto per-processor timing, a
//! discrete-event clock books pipelined subgraph executions, and a
//! unified-memory pool accounts for loaded weights.

pub mod clock;
pub mod latency;
pub mod memory;
pub mod profile;

pub use clock::SocSim;
pub use latency::{BaseLatencies, LatencyModel};
pub use memory::{BlobId, MemoryBreakdown, MemoryPool};
pub use profile::{order_label, Platform, Processor, ProcessorModel};
