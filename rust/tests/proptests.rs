//! Property-based tests over the coordinator invariants, driven by the
//! in-repo `propcheck` framework (DESIGN.md §Validation):
//!
//! * stitched index ↔ composition round-trips for arbitrary (V, S);
//! * the optimizer returns only SLO-feasible selections whenever any
//!   exist, and its order is always drawn from Ω;
//! * the preloader never exceeds its budget, for any budget;
//! * hotness scores are non-negative and position-normalized;
//! * the SocSim clock is monotone and never double-books a processor;
//! * the memory pool never exceeds capacity under arbitrary op streams.

use std::collections::BTreeMap;

use sparseloom::planner::provider::SynthesizingProvider;
use sparseloom::planner::{
    algo, memory, CostModel, PressureSignal, VariantProvider, VariantQuery,
};
use sparseloom::preloader::{full_preload_bytes, Hotness};
use sparseloom::profiler::{profile_task, ProfilerConfig, TaskProfile};
use sparseloom::propcheck::{check, usize_in, vec_of, Gen};
use sparseloom::scenario::{
    Admission, Dispatch, PlannerConfig, Scenario, ShardAssignment, Sharding,
};
use sparseloom::soc::{
    BaseLatencies, BlobId, LatencyModel, MemoryPool, Platform, Processor, SocSim,
};
use sparseloom::stitching::{Composition, StitchSpace};
use sparseloom::util::Rng;
use sparseloom::workload::{placement_orders, Query, Slo};
use sparseloom::zoo::{
    DType, HloArtifact, KernelPath, Precision, SubgraphWeights, TaskVariant,
    TaskZoo, TensorSpec, VariantSpec, VariantType, Zoo,
};

// ---------------------------------------------------------------------
// Synthetic TaskZoo generator (arbitrary V, S, accuracies, sizes).
// ---------------------------------------------------------------------

fn synth_taskzoo(v: usize, s: usize, seed: u64) -> TaskZoo {
    let mut rng = Rng::new(seed);
    let types = [
        (VariantType::Dense, KernelPath::Dense, 0.0),
        (VariantType::Int8, KernelPath::Quant, 0.0),
        (VariantType::Structured, KernelPath::BlockSparse, 0.5),
        (VariantType::Unstructured, KernelPath::Masked, 0.8),
    ];
    let mut variants = Vec::new();
    for i in 0..v {
        let (vt, kp, sp) = types[i % types.len()];
        let acc = 0.4 + 0.6 * rng.f64();
        let subgraphs = (0..s)
            .map(|_| SubgraphWeights {
                file: "/dev/null".into(),
                bytes: 500 + rng.below(2000) as u64,
                params: vec![TensorSpec { dtype: DType::F32, shape: vec![4] }],
            })
            .collect();
        variants.push(TaskVariant {
            spec: VariantSpec {
                name: format!("v{i}"),
                vtype: vt,
                sparsity: sp,
                kernel_path: kp,
                precision: Precision::Fp32,
            },
            accuracy: acc,
            subgraphs,
        });
    }
    let mut hlo = BTreeMap::new();
    for sg in 0..s {
        for path in [
            KernelPath::Dense,
            KernelPath::Quant,
            KernelPath::BlockSparse,
            KernelPath::Masked,
        ] {
            hlo.insert(
                (sg, path, 1),
                HloArtifact {
                    file: "/dev/null".into(),
                    flops: 1e5,
                    bytes_accessed: 1e4,
                    params: vec![],
                    input_dim: 8,
                    output_dim: 8,
                },
            );
        }
    }
    TaskZoo {
        name: format!("synth{seed}"),
        family: "synth".into(),
        input_dim: 8,
        iface: vec![8; s + 1],
        variants,
        hlo,
    }
}

fn synth_profile(
    v: usize,
    s: usize,
    seed: u64,
) -> (TaskZoo, TaskProfile, Vec<Vec<Processor>>, LatencyModel) {
    let tz = synth_taskzoo(v, s, seed);
    let mut base = BaseLatencies::new();
    let mut rng = Rng::new(seed ^ 0xabc);
    for sg in 0..s {
        for path in [
            KernelPath::Dense,
            KernelPath::Quant,
            KernelPath::BlockSparse,
            KernelPath::Masked,
        ] {
            base.set(&tz.name, sg, path, 1.0 + 9.0 * rng.f64());
        }
    }
    let plat = Platform::desktop();
    let orders = placement_orders(&plat, s);
    let lm = LatencyModel::new(plat, base);
    let space = StitchSpace::for_task(&tz);
    let oracle: Vec<f64> = space
        .iter()
        .map(|c| {
            let mean: f64 =
                c.0.iter().map(|&i| tz.variants[i].accuracy).sum::<f64>() / s as f64;
            mean.clamp(0.0, 1.0)
        })
        .collect();
    let cfg = ProfilerConfig { train_samples: (space.len() / 3).max(8), ..Default::default() };
    let p = profile_task(&tz, &lm, &oracle, &cfg, true);
    (tz, p, orders, lm)
}

// ---------------------------------------------------------------------
// Properties
// ---------------------------------------------------------------------

#[test]
fn prop_stitched_index_roundtrip() {
    // (V, S) pairs with V in [1,12], S in [1,4]; every index round-trips.
    let gen: Gen<Vec<usize>> = vec_of(usize_in(1, 12), 2);
    check("index_roundtrip", &gen, 120, 11, |dims| {
        let v = dims[0];
        let s = (dims[1] % 4) + 1;
        let space = StitchSpace::new(v, s);
        for k in (0..space.len()).step_by((space.len() / 50).max(1)) {
            let c = space.composition(k);
            if space.index(&c) != k {
                return Err(format!("V={v} S={s} k={k} → {:?}", c));
            }
            if c.subgraphs() != s {
                return Err(format!("wrong length {:?}", c));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_optimizer_respects_slos() {
    let gen = usize_in(0, 10_000);
    check("optimizer_feasibility", &gen, 40, 12, |&seed| {
        let (_tz, p, orders, _lm) = synth_profile(4, 3, seed as u64);
        let mut rng = Rng::new(seed as u64 ^ 0x55);
        let slo = Slo {
            min_accuracy: 0.3 + 0.6 * rng.f64(),
            max_latency_ms: 2.0 + 30.0 * rng.f64(),
        };
        let profiles = BTreeMap::from([(p.task.clone(), p.clone())]);
        let slos = BTreeMap::from([(p.task.clone(), slo)]);
        let plan = algo::optimize(&CostModel::unit(), &profiles, &slos, &orders);
        if !orders.contains(&plan.order) {
            return Err(format!("order {:?} ∉ Ω", plan.order));
        }
        let theta = algo::feasible_set(&CostModel::unit(), &p, &slo, &orders);
        match plan.selections[&p.task] {
            Some(sel) => {
                if theta.indices.is_empty() {
                    return Err("selected from an empty Θ".into());
                }
                if p.accuracy(sel.stitched_index) < slo.min_accuracy {
                    return Err("accuracy constraint violated".into());
                }
            }
            None => {
                if !theta.indices.is_empty() {
                    return Err(format!(
                        "Θ has {} candidates but nothing selected",
                        theta.indices.len()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_selected_variant_is_minimal_under_chosen_order() {
    let gen = usize_in(0, 10_000);
    check("optimizer_minimality", &gen, 30, 13, |&seed| {
        let (_tz, p, orders, _lm) = synth_profile(4, 3, seed as u64);
        let slo = Slo { min_accuracy: 0.0, max_latency_ms: f64::INFINITY };
        let profiles = BTreeMap::from([(p.task.clone(), p.clone())]);
        let slos = BTreeMap::from([(p.task.clone(), slo)]);
        let plan = algo::optimize(&CostModel::unit(), &profiles, &slos, &orders);
        let sel = plan.selections[&p.task].ok_or("nothing selected")?;
        for k in 0..p.space.len() {
            if let Some(l) = p.latency_est(&p.space.composition(k), &plan.order) {
                if l + 1e-12 < sel.latency_ms {
                    return Err(format!(
                        "k={k} at {l} beats selection {}",
                        sel.latency_ms
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_preloader_never_exceeds_budget() {
    let gen: Gen<Vec<usize>> = vec_of(usize_in(0, 10_000), 2);
    check("preload_budget", &gen, 50, 14, |dims| {
        let seed = dims[0] as u64;
        let (tz, p, orders, _lm) = synth_profile(5, 3, seed);
        let slos: Vec<Slo> = (0..5)
            .map(|i| Slo {
                min_accuracy: 0.4 + 0.1 * i as f64,
                max_latency_ms: f64::INFINITY,
            })
            .collect();
        let h = Hotness::compute(&p, &slos, &orders);
        let full = full_preload_bytes(&[&tz]);
        let budget = (dims[1] as u64).min(full * 2);
        let plan = memory::preload(&[(&tz, &h)], budget);
        if plan.total_bytes > budget {
            return Err(format!("{} > {budget}", plan.total_bytes));
        }
        // No duplicate blobs.
        let mut seen = std::collections::HashSet::new();
        for b in &plan.blobs {
            if !seen.insert(b.clone()) {
                return Err(format!("duplicate {b:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hotness_nonnegative_and_normalized() {
    let gen = usize_in(0, 10_000);
    check("hotness_normalized", &gen, 40, 15, |&seed| {
        let (_tz, p, orders, _lm) = synth_profile(4, 3, seed as u64);
        let slos: Vec<Slo> = (0..6)
            .map(|i| Slo {
                min_accuracy: 0.3 + 0.1 * i as f64,
                max_latency_ms: f64::INFINITY,
            })
            .collect();
        let h = Hotness::compute(&p, &slos, &orders);
        let feasible_cfgs = slos
            .iter()
            .filter(|s| !algo::feasible_set(&CostModel::unit(), &p, s, &orders).is_empty())
            .count() as f64;
        for (j, row) in h.scores.iter().enumerate() {
            let sum: f64 = row.iter().sum();
            if row.iter().any(|&x| x < 0.0) {
                return Err(format!("negative hotness at {j}"));
            }
            if (sum - feasible_cfgs).abs() > 1e-6 {
                return Err(format!(
                    "position {j} sums to {sum}, want {feasible_cfgs}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_socsim_monotone_and_exclusive() {
    let gen: Gen<Vec<usize>> = vec_of(usize_in(0, 999), 24);
    check("socsim_exclusive", &gen, 60, 16, |jobs| {
        let procs = [Processor::Cpu, Processor::Gpu, Processor::Npu];
        let mut sim = SocSim::new(&procs);
        let mut booked: Vec<(Processor, f64, f64)> = Vec::new();
        for (i, &job) in jobs.iter().enumerate() {
            let proc = procs[job % 3];
            let ready = (job / 3 % 20) as f64;
            let dur = 1.0 + (job % 7) as f64;
            let (start, end) = sim.book(proc, ready, dur);
            if start < ready {
                return Err(format!("job {i} started before ready"));
            }
            if (end - start - dur).abs() > 1e-9 {
                return Err("duration not preserved".into());
            }
            for &(p2, s2, e2) in &booked {
                if p2 == proc && start < e2 - 1e-9 && s2 < end - 1e-9 {
                    return Err(format!(
                        "overlap on {proc:?}: [{start},{end}] vs [{s2},{e2}]"
                    ));
                }
            }
            booked.push((proc, start, end));
        }
        Ok(())
    });
}

#[test]
fn prop_memory_pool_capacity_invariant() {
    let gen: Gen<Vec<usize>> = vec_of(usize_in(0, 9999), 40);
    check("pool_capacity", &gen, 60, 17, |ops| {
        let mut pool = MemoryPool::new(10_000);
        for (i, &op) in ops.iter().enumerate() {
            let id = BlobId::new("t", op % 7, op / 7 % 3);
            match op % 4 {
                0 | 1 => {
                    let bytes = 100 + (op % 3000) as u64;
                    let _ = pool.load(id, bytes);
                }
                2 => {
                    let _ = pool.evict(&id);
                }
                _ => {
                    let _ = pool.make_room((op % 5000) as u64);
                }
            }
            if pool.used() > pool.capacity() {
                return Err(format!("op {i}: used {} > cap", pool.used()));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Scenario JSON schema round-trip (arbitrary scenarios, all fields).
// ---------------------------------------------------------------------

fn arbitrary_scenario(seed: u64) -> Scenario {
    let mut rng = Rng::new(seed);
    let n_tasks = 1 + rng.below(4);
    let tasks: Vec<String> = (0..n_tasks).map(|i| format!("t{i}")).collect();
    fn slo(rng: &mut Rng) -> Slo {
        Slo {
            min_accuracy: rng.f64(),
            max_latency_ms: 1.0 + 200.0 * rng.f64(),
        }
    }
    let phases = 1 + rng.below(3);
    let mut schedule: Vec<std::collections::BTreeMap<String, Slo>> = Vec::new();
    for _ in 0..phases {
        let mut cfg = std::collections::BTreeMap::new();
        for t in &tasks {
            cfg.insert(t.clone(), slo(&mut rng));
        }
        schedule.push(cfg);
    }
    let first = schedule[0].clone();
    let mut sc = match rng.below(4) {
        0 => Scenario::closed_loop(&tasks, first)
            .with_queries(1 + rng.below(50))
            .with_stagger_ms(5.0 * rng.f64()),
        1 => Scenario::poisson(
            &tasks,
            first,
            1.0 + 50.0 * rng.f64(),
            100.0 + 2_000.0 * rng.f64(),
        ),
        2 => Scenario::bursty(
            &tasks,
            first,
            1.0 + 10.0 * rng.f64(),
            20.0 + 100.0 * rng.f64(),
            50.0 + 500.0 * rng.f64(),
            100.0 + 2_000.0 * rng.f64(),
        ),
        _ => {
            let n_q = rng.below(20);
            let mut queries = Vec::new();
            for i in 0..n_q {
                queries.push(Query {
                    task: tasks[rng.below(tasks.len())].clone(),
                    arrival_ms: 100.0 * rng.f64(),
                    id: i as u64,
                });
            }
            Scenario::trace(&tasks, first, queries)
        }
    };
    sc = sc.with_schedule(schedule);
    let admission = match rng.below(5) {
        0 => Admission::Always,
        1 => Admission::QueueCap { max_queued: rng.below(16) },
        2 => Admission::Deadline { slack: 0.5 + 3.0 * rng.f64() },
        3 => Admission::Predictive {
            horizon_ms: 20.0 + 500.0 * rng.f64(),
            headroom: 0.5 + 2.0 * rng.f64(),
        },
        _ => {
            let mut weights = std::collections::BTreeMap::new();
            for t in &tasks {
                if rng.f64() < 0.5 {
                    weights.insert(t.clone(), 4.0 * rng.f64());
                }
            }
            Admission::Fair { slack: 0.5 + 2.0 * rng.f64(), weights }
        }
    };
    sc = sc.with_admission(admission);
    sc = sc.with_dispatch(Dispatch {
        max_batch: 1 + rng.below(8),
        min_queue: rng.below(6),
    });
    let shards = 1 + rng.below(3);
    let assignment = if rng.f64() < 0.5 {
        ShardAssignment::Hash
    } else {
        let mut map = std::collections::BTreeMap::new();
        for t in &tasks {
            if rng.f64() < 0.7 {
                map.insert(t.clone(), rng.below(shards + 1));
            }
        }
        ShardAssignment::Explicit(map)
    };
    sc = sc.with_sharding(Sharding { shards, assignment });
    sc = sc.with_planner(PlannerConfig {
        batch_aware: rng.f64() < 0.5,
        replan: rng.f64() < 0.5,
        steal: rng.f64() < 0.5,
        warm_migrate: rng.f64() < 0.5,
        predictive: rng.f64() < 0.5,
        horizon_ms: 50.0 + 500.0 * rng.f64(),
        saturation_slack: 1.0 + 4.0 * rng.f64(),
        max_migrations: rng.below(4),
        epoch_ms: if rng.f64() < 0.5 { 0.0 } else { 10.0 + 40.0 * rng.f64() },
        synthesize: rng.f64() < 0.5,
    });
    if rng.f64() < 0.5 {
        let n_uni = rng.below(4);
        let mut universe = Vec::new();
        for _ in 0..n_uni {
            universe.push(slo(&mut rng));
        }
        sc = sc.with_universe(universe);
    }
    sc.with_seed(rng.next_u64())
}

#[test]
fn prop_scenario_json_schema_roundtrip() {
    // The full schema — arrival kinds, admission (incl. Fair weights),
    // the PR 2 dispatch/sharding fields, the planner config, schedule,
    // universe, u64 seeds — must survive to_json → parse → from_json
    // exactly, as both a field-level and a re-serialization identity.
    let gen = usize_in(0, 1_000_000);
    check("scenario_json_roundtrip", &gen, 150, 19, |&seed| {
        let sc = arbitrary_scenario(seed as u64);
        let text = sc.to_json().to_string_pretty();
        let parsed =
            sparseloom::json::parse(&text).map_err(|e| format!("parse: {e}"))?;
        let back =
            Scenario::from_json(&parsed).map_err(|e| format!("from_json: {e}"))?;
        if back.name != sc.name
            || back.tasks != sc.tasks
            || back.seed != sc.seed
            || back.admission != sc.admission
            || back.dispatch != sc.dispatch
            || back.sharding != sc.sharding
            || back.planner != sc.planner
            || back.schedule != sc.schedule
            || back.universe != sc.universe
        {
            return Err("field mismatch after round-trip".into());
        }
        // Serialization is a fixed point (covers Arrival, which has no
        // PartialEq) and streams replay identically per phase.
        if back.to_json() != sc.to_json() {
            return Err("re-serialization differs".into());
        }
        for phase in 0..sc.phases() {
            let a = sc.stream(phase);
            let b = back.stream(phase);
            if a.len() != b.len() {
                return Err(format!("phase {phase} stream length differs"));
            }
            for (x, y) in a.iter().zip(&b) {
                if x.task != y.task
                    || x.id != y.id
                    || (x.arrival_ms - y.arrival_ms).abs() > 1e-12
                {
                    return Err(format!("phase {phase} stream differs"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_latency_estimate_is_additive_lower_bound_of_truth() {
    let gen = usize_in(0, 10_000);
    check("eq5_lower_bound", &gen, 40, 18, |&seed| {
        let (_tz, p, orders, _lm) = synth_profile(4, 3, seed as u64);
        let mut rng = Rng::new(seed as u64 ^ 7);
        for _ in 0..20 {
            let k = rng.below(p.space.len());
            let comp: Composition = p.space.composition(k);
            let order = rng.choose(&orders);
            match (p.latency_est(&comp, order), p.latency_true(&comp, order)) {
                (Some(e), Some(t)) => {
                    if e > t + 1e-9 {
                        return Err(format!("estimate {e} above truth {t}"));
                    }
                }
                (None, Some(_)) | (Some(_), None) => {
                    return Err("support disagreement".into());
                }
                (None, None) => {}
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Online synthesis (the VariantProvider search path).
// ---------------------------------------------------------------------

#[test]
fn prop_synthesized_compositions_roundtrip_and_align() {
    // The synthesizing provider may only ever emit stitched indices
    // that decode to structurally valid compositions: the index
    // round-trips through the V^S space within `try_len` bounds, the
    // digit string has exactly S in-alphabet positions, and every
    // chosen variant is iface-aligned with the zoo (the SL-FEA-003
    // contract that `sparselint` enforces statically).
    let gen: Gen<Vec<usize>> = vec_of(usize_in(0, 10_000), 3);
    check("synthesis_roundtrip", &gen, 30, 21, |dims| {
        let seed = dims[0] as u64;
        let v = 2 + dims[1] % 3; // V ∈ [2,4]
        let s = 2 + dims[2] % 2; // S ∈ [2,3]
        let (tz, p, orders, lm) = synth_profile(v, s, seed);
        let name = tz.name.clone();
        let profiles = BTreeMap::from([(name.clone(), p.clone())]);
        let zoo = Zoo {
            root: std::path::PathBuf::from("/nonexistent"),
            seed,
            zoo_name: "prop".into(),
            subgraphs: s,
            n_classes: 10,
            batch_sizes: vec![1],
            probe_batch: 4,
            n_eval: 16,
            tasks: BTreeMap::from([(name.clone(), tz)]),
        };
        let provider = SynthesizingProvider::new(&zoo, &lm, &profiles, orders);
        let n = p.space.try_len().map_err(|e| format!("try_len: {e}"))?;
        let tzr = zoo.task(&name).map_err(|e| format!("{e}"))?;
        if tzr.iface.len() != s + 1 {
            return Err(format!("iface has {} boundaries, want S+1", tzr.iface.len()));
        }
        let mut rng = Rng::new(seed ^ 0x5717);
        for trial in 0..6usize {
            let q = VariantQuery {
                task: name.clone(),
                slo: Slo {
                    min_accuracy: 0.3 + 0.5 * rng.f64(),
                    max_latency_ms: 1e9,
                },
                feasible_orders: Vec::new(),
                commit_order: None,
                batch: 1.0 + 7.0 * rng.f64(),
                pool_share: if rng.f64() < 0.5 {
                    u64::MAX
                } else {
                    1_000 + rng.below(8_000) as u64
                },
                phase: trial,
                pressure: Some(PressureSignal {
                    forecast_ms: 50.0,
                    threshold_ms: 5.0,
                    pool_utilization: 1.0,
                }),
            };
            let Some(dec) = provider.provide(&q) else {
                continue; // floor too high for this zoo: nothing admissible
            };
            let k = dec.selection.stitched_index;
            if k >= n {
                return Err(format!("index {k} out of V^S = {n}"));
            }
            let comp = p.space.composition(k);
            if comp.to_index(p.space.n_variants) != k {
                return Err(format!("k={k} does not round-trip: {:?}", comp));
            }
            if comp.subgraphs() != s {
                return Err(format!("{} digits, want S={s}", comp.subgraphs()));
            }
            for (j, &vi) in comp.0.iter().enumerate() {
                if vi >= v {
                    return Err(format!("digit {j} picks variant {vi} ∉ [0,{v})"));
                }
                if tzr.variants[vi].subgraphs.len() != s {
                    return Err(format!(
                        "variant {vi} ships {} subgraphs, want {s}",
                        tzr.variants[vi].subgraphs.len()
                    ));
                }
            }
            if dec.selection.accuracy + 1e-12 < q.slo.min_accuracy {
                return Err(format!(
                    "accuracy {} below floor {}",
                    dec.selection.accuracy, q.slo.min_accuracy
                ));
            }
        }
        Ok(())
    });
}
