//! Placement-order bench (paper Table 2 / Fig. 13): times the *real*
//! PJRT stitched-chain execution for the paper's six variant mixes, and
//! reports the platform-model latencies per placement order.
//!
//! Run: `cargo bench --bench placement_orders`

use sparseloom::benchkit::Bench;
use sparseloom::experiments::Ctx;
use sparseloom::profiler::profile_task_exhaustive;
use sparseloom::runtime::Runtime;
use sparseloom::soc::{order_label, Platform};
use sparseloom::stitching::Composition;
use sparseloom::workload::placement_orders;

fn main() -> anyhow::Result<()> {
    let Ok(ctx) = Ctx::load("artifacts", false) else {
        eprintln!("no artifacts/ — run `make artifacts` first");
        return Ok(());
    };
    let task = "imgcls";
    let tz = ctx.zoo.task(task)?;
    let vi = |name: &str| tz.variant_by_name(name).unwrap().0;
    let (d, q, pu, ps) = (vi("dense"), vi("int8"), vi("unstr80"), vi("struct50"));
    let mixes: Vec<(&str, Composition)> = vec![
        ("P-Q-P", Composition(vec![pu, q, ps])),
        ("P-P-Q", Composition(vec![pu, ps, q])),
        ("D-D-P", Composition(vec![d, d, pu])),
        ("D-P-Q", Composition(vec![d, pu, q])),
        ("Q-P-D", Composition(vec![q, ps, d])),
        ("P-D-Q", Composition(vec![ps, d, q])),
    ];

    // Real PJRT end-to-end chains (host CPU; order-independent).
    println!("\n== real PJRT stitched-chain execution ({task}, batch 1) ==\n");
    Bench::header();
    let rt = Runtime::new()?;
    let input: Vec<f32> = (0..tz.input_dim).map(|i| (i as f32 * 0.21).sin()).collect();
    let mut b = Bench::quick();
    for (name, comp) in &mixes {
        // warm caches
        let _ = rt.run_chain(&ctx.zoo, task, &comp.0, 1, &input)?;
        b.case(&format!("chain {name}"), || {
            rt.run_chain(&ctx.zoo, task, &comp.0, 1, &input).unwrap().0[0]
        });
    }

    // Platform-model projection across all six desktop orders (Table 2).
    println!("\n== platform-model latency (ms) per order (Table 2) ==\n");
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let oracle = ctx.zoo.load_oracle(task)?;
    let p = profile_task_exhaustive(tz, &lm, &oracle);
    let orders = placement_orders(&platform, ctx.zoo.subgraphs);
    print!("{:<8}", "order");
    for (name, _) in &mixes {
        print!("{name:>8}");
    }
    println!();
    for order in &orders {
        print!("{:<8}", order_label(order));
        for (_, comp) in &mixes {
            match p.latency_true(comp, order) {
                Some(l) => print!("{l:>8.3}"),
                None => print!("{:>8}", "n/s"),
            }
        }
        println!();
    }
    Ok(())
}
