//! Batch-aware Algorithm 1 with a pruned candidate walk.
//!
//! This is the canonical implementation of the paper's Sparsity-Aware
//! Optimizer (§3.3); `crate::optimizer`'s free functions are thin
//! deprecated shims over it at the unit (batch-1) [`CostModel`]. The
//! math notes live in DESIGN.md §"Algorithm 1".
//!
//! Two prunes speed up the |Ω| × V^S hot loop without changing its
//! result (asserted by `pruned_feasible_set_matches_reference`):
//!
//! * **Order-level**: an order whose per-position latency *minima*
//!   already exceed the SLO bound cannot make any candidate feasible
//!   and is dropped from the scan entirely.
//! * **Candidate-level**: the accuracy digit is order-independent, so a
//!   failed accuracy check skips the whole per-order latency scan; the
//!   per-order partial latency sum aborts as soon as it crosses the
//!   bound, and the order scan short-circuits on the first feasible
//!   order.

use std::collections::BTreeMap;

use crate::optimizer::{CandidateSet, Plan, Selection};
use crate::profiler::TaskProfile;
use crate::soc::Processor;
use crate::workload::Slo;

use super::cost::CostModel;

/// Lower bound on any candidate's latency under `order`: the sum over
/// positions of the fastest supported variant there. `None` when some
/// position supports no variant at all on its assigned processor.
fn order_lower_bound(p: &TaskProfile, order: &[Processor]) -> Option<f64> {
    let mut total = 0.0;
    for (j, proc) in order.iter().enumerate() {
        let mut best = f64::INFINITY;
        for cell in &p.sg_lat[j] {
            if let Some(ms) = cell[proc.idx()] {
                if ms < best {
                    best = ms;
                }
            }
        }
        if !best.is_finite() {
            return None;
        }
        total += best;
    }
    Some(total)
}

/// Early-exit Eq. 5: is the additive latency of `digits` under `order`
/// within `bound`? Aborts the digit walk as soon as the partial sum
/// crosses the bound or a position is unsupported.
fn within_bound(
    p: &TaskProfile,
    digits: &[usize],
    order: &[Processor],
    bound: f64,
) -> bool {
    let mut total = 0.0;
    for (j, (&vi, proc)) in digits.iter().zip(order).enumerate() {
        match p.sg_lat[j][vi][proc.idx()] {
            Some(ms) => {
                total += ms;
                if total > bound {
                    return false;
                }
            }
            None => return false,
        }
    }
    true
}

/// Step 1 of Algorithm 1 (pruned, batch-aware): compute Θᵗ — the
/// stitched indices whose estimated accuracy meets the SLO and whose
/// batch-scaled latency fits the bound under at least one order in Ω.
pub fn feasible_set(
    cost: &CostModel,
    profile: &TaskProfile,
    slo: &Slo,
    orders: &[Vec<Processor>],
) -> CandidateSet {
    let v = profile.space.n_variants;
    let s = profile.space.n_subgraphs;
    // The batch factor scales every candidate equally, so it folds into
    // the latency bound once instead of into every partial sum.
    let bound = slo.max_latency_ms / cost.batch_factor(&profile.task);
    let live: Vec<&[Processor]> = orders
        .iter()
        .map(|o| o.as_slice())
        .filter(|o| order_lower_bound(profile, o).map(|lb| lb <= bound).unwrap_or(false))
        .collect();
    let mut indices = Vec::new();
    if live.is_empty() {
        return CandidateSet { indices };
    }
    let mut digits = vec![0usize; s];
    for k in 0..profile.space.len() {
        if profile.accuracy(k) >= slo.min_accuracy
            && live.iter().any(|o| within_bound(profile, &digits, o, bound))
        {
            indices.push(k);
        }
        // increment base-V odometer (little-endian on the last digit)
        for j in (0..s).rev() {
            digits[j] += 1;
            if digits[j] < v {
                break;
            }
            digits[j] = 0;
        }
    }
    CandidateSet { indices }
}

/// Algorithm 1, complete (batch-aware): joint placement-order + variant
/// selection. Equivalent to [`optimize_weighted`] with no weights.
///
/// Planning is driven by the SLO map: tasks with an SLO but no profile
/// are skipped, and profiles without an SLO are left unplanned — shard
/// sub-scenarios hand the planner exactly this shape (their schedules
/// are filtered to the shard's partition while the profile map stays
/// global).
pub fn optimize(
    cost: &CostModel,
    profiles: &BTreeMap<String, TaskProfile>,
    slos: &BTreeMap<String, Slo>,
    orders: &[Vec<Processor>],
) -> Plan {
    optimize_weighted(cost, profiles, slos, orders, &BTreeMap::new())
}

/// [`optimize`] with per-task arrival weights: step 2's objective
/// becomes the *weighted* mean best latency, so tasks expected to see
/// more traffic (the `PlanContext::arrival_hint`) pull the shared
/// placement order toward their optimum. Missing weights default to
/// 1.0; an empty map reproduces the paper's unweighted objective.
pub fn optimize_weighted(
    cost: &CostModel,
    profiles: &BTreeMap<String, TaskProfile>,
    slos: &BTreeMap<String, Slo>,
    orders: &[Vec<Processor>],
    weights: &BTreeMap<String, f64>,
) -> Plan {
    assert!(!orders.is_empty(), "empty order set Ω");

    let planned: Vec<(&String, &TaskProfile, &Slo)> = slos
        .iter()
        .filter_map(|(name, slo)| profiles.get(name).map(|p| (name, p, slo)))
        .collect();

    // Step 1: Θᵗ per planned task.
    let theta: BTreeMap<&str, CandidateSet> = planned
        .iter()
        .map(|&(name, p, slo)| (name.as_str(), feasible_set(cost, p, slo, orders)))
        .collect();

    // Step 2: pick p⃗* minimizing the (weighted) mean best latency.
    let mut best: Option<(f64, usize)> = None;
    for (oi, order) in orders.iter().enumerate() {
        let mut sum = 0.0;
        let mut weight_sum = 0.0;
        for &(name, p, _) in &planned {
            let cands = &theta[name.as_str()];
            let mut task_best = f64::INFINITY;
            for &k in &cands.indices {
                let comp = p.space.composition(k);
                if let Some(l) = cost.latency(p, &comp, order) {
                    if l < task_best {
                        task_best = l;
                    }
                }
            }
            if task_best.is_finite() {
                let w = weights.get(name.as_str()).copied().unwrap_or(1.0).max(0.0);
                sum += w * task_best;
                weight_sum += w;
            }
        }
        if weight_sum <= 0.0 {
            continue;
        }
        let mean = sum / weight_sum;
        if best.map(|(b, _)| mean < b).unwrap_or(true) {
            best = Some((mean, oi));
        }
    }
    let (mean_latency_ms, oi) = best.unwrap_or((f64::INFINITY, 0));
    let order = orders[oi].clone();

    // Step 3: final per-task selection under p⃗*.
    let mut selections = BTreeMap::new();
    for &(name, p, _) in &planned {
        let cands = &theta[name.as_str()];
        let mut choice: Option<Selection> = None;
        for &k in &cands.indices {
            let comp = p.space.composition(k);
            if let Some(l) = cost.latency(p, &comp, &order) {
                if choice.map(|c| l < c.latency_ms).unwrap_or(true) {
                    choice = Some(Selection {
                        stitched_index: k,
                        latency_ms: l,
                        accuracy: p.accuracy(k),
                    });
                }
            }
        }
        selections.insert(name.clone(), choice);
    }

    Plan { order, selections, mean_latency_ms }
}

/// Restricted Algorithm 1 for the no-stitching baselines: only pure
/// compositions are considered (classic adaptive-variant selection).
pub fn optimize_pure_only(
    cost: &CostModel,
    profiles: &BTreeMap<String, TaskProfile>,
    slos: &BTreeMap<String, Slo>,
    orders: &[Vec<Processor>],
) -> Plan {
    let restricted: BTreeMap<String, TaskProfile> = profiles
        .iter()
        .map(|(name, p)| {
            let mut r = p.clone();
            // Suppress all non-pure variants by zeroing their accuracy
            // (they will fail any positive accuracy SLO) — latency table
            // untouched so pure entries behave identically.
            for k in 0..r.space.len() {
                if !r.space.composition(k).is_pure() {
                    r.acc_pred[k] = -1.0;
                }
            }
            (name.clone(), r)
        })
        .collect();
    optimize(cost, &restricted, slos, orders)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixtures;
    use crate::soc::LatencyModel;

    fn setup() -> (BTreeMap<String, TaskProfile>, LatencyModel, Vec<Vec<Processor>>) {
        let (zoo, lm, profiles) = fixtures::trio();
        let orders =
            crate::workload::placement_orders(&lm.platform, zoo.subgraphs);
        (profiles, lm, orders)
    }

    /// The unpruned reference walk (the pre-planner `feasible_set`).
    fn reference_feasible_set(
        cost: &CostModel,
        p: &TaskProfile,
        slo: &Slo,
        orders: &[Vec<Processor>],
    ) -> Vec<usize> {
        let mut out = Vec::new();
        for k in 0..p.space.len() {
            if p.accuracy(k) < slo.min_accuracy {
                continue;
            }
            let comp = p.space.composition(k);
            let ok = orders.iter().any(|o| {
                cost.latency(p, &comp, o)
                    .map(|l| l <= slo.max_latency_ms)
                    .unwrap_or(false)
            });
            if ok {
                out.push(k);
            }
        }
        out
    }

    #[test]
    fn pruned_feasible_set_matches_reference() {
        let (profiles, lm, orders) = setup();
        // Sweep bounds from impossible to lax; the pruned walk must
        // agree with the naive reference at every point, batch-aware
        // included.
        for hint in [1.0, 3.0] {
            let cost = CostModel::batch_aware(&lm, hint);
            for p in profiles.values() {
                for acc in [0.0, 0.8, 0.95] {
                    for lat in [0.001, 5.0, 12.0, 30.0, 1e9] {
                        let slo = Slo { min_accuracy: acc, max_latency_ms: lat };
                        let pruned = feasible_set(&cost, p, &slo, &orders);
                        let naive = reference_feasible_set(&cost, p, &slo, &orders);
                        assert_eq!(
                            pruned.indices, naive,
                            "{} acc={acc} lat={lat} hint={hint}",
                            p.task
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn batch_hint_only_shrinks_feasible_sets() {
        let (profiles, lm, orders) = setup();
        let p = &profiles["alpha"];
        let slo = Slo { min_accuracy: 0.5, max_latency_ms: 20.0 };
        let unit = feasible_set(&CostModel::unit(), p, &slo, &orders);
        let batched =
            feasible_set(&CostModel::batch_aware(&lm, 4.0), p, &slo, &orders);
        assert!(batched.len() <= unit.len());
        // A batched-feasible candidate is always batch-1 feasible.
        for k in &batched.indices {
            assert!(unit.indices.contains(k));
        }
    }

    #[test]
    fn optimize_skips_tasks_without_slos() {
        // Shard sub-scenarios plan with a filtered SLO map over the full
        // profile map; the planner must plan exactly the SLO'd tasks.
        let (profiles, _lm, orders) = setup();
        let slos = BTreeMap::from([(
            "beta".to_string(),
            Slo { min_accuracy: 0.5, max_latency_ms: 1e9 },
        )]);
        let plan = optimize(&CostModel::unit(), &profiles, &slos, &orders);
        assert_eq!(plan.selections.len(), 1);
        assert!(plan.selections["beta"].is_some());
        assert!(orders.contains(&plan.order));
    }

    #[test]
    fn arrival_weights_can_steer_the_order() {
        let (profiles, _lm, orders) = setup();
        let slos: BTreeMap<String, Slo> = profiles
            .keys()
            .map(|n| (n.clone(), Slo { min_accuracy: 0.0, max_latency_ms: 1e9 }))
            .collect();
        let cost = CostModel::unit();
        // Degenerate all-weight-on-one-task objective: the joint order
        // must be at least as good for that task as the unweighted one.
        let heavy = BTreeMap::from([("gamma".to_string(), 1e6)]);
        let weighted = optimize_weighted(&cost, &profiles, &slos, &orders, &heavy);
        let solo_slos = BTreeMap::from([("gamma".to_string(), slos["gamma"])]);
        let solo = optimize(&cost, &profiles, &solo_slos, &orders);
        let gamma_best = |plan: &Plan| plan.selections["gamma"].unwrap().latency_ms;
        // Tolerance: the residual unit weights can shift the weighted
        // argmin by at most (Σ other latencies)/1e6 ≈ microseconds.
        assert!(gamma_best(&weighted) <= gamma_best(&solo) + 1e-3);
    }
}
