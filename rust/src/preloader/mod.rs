//! Hot-Subgraph Preloader (paper §3.4, Algorithm 2).
//!
//! Preloading every subgraph of every variant hides switching latency
//! but is memory-prohibitive (Fig. 5b). SparseLoom scores each original
//! subgraph `s_j^{t,i}` by **hotness** (Eq. 7) — its occurrence
//! frequency across the SLO-feasible variant sets Θᵗ(σ) over all SLO
//! configurations σ ∈ Ψ — and greedily preloads the hottest subgraphs
//! at each position under a global memory budget.


use crate::planner::{algo, CostModel};
use crate::profiler::TaskProfile;
use crate::soc::{BlobId, Processor};
use crate::workload::Slo;
use crate::zoo::TaskZoo;

/// Hotness scores for one task: `scores[j][i]` for subgraph position j,
/// original variant i.
#[derive(Clone, Debug)]
pub struct Hotness {
    pub task: String,
    pub scores: Vec<Vec<f64>>,
}

impl Hotness {
    /// Eq. 7: H[s_j^{t,i}] = Σ_σ Occur(s_j^{t,i}, Θᵗ(σ)) / |Θᵗ(σ)|.
    pub fn compute(
        profile: &TaskProfile,
        slo_set: &[Slo],
        orders: &[Vec<Processor>],
    ) -> Hotness {
        let s = profile.space.n_subgraphs;
        let v = profile.space.n_variants;
        let n = profile.space.len();
        let mut scores = vec![vec![0.0f64; v]; s];
        // Precompute each composition's best latency over Ω once — the
        // per-σ feasibility test then costs two comparisons instead of
        // |Ω| latency sums (|Ψ|×V^S×|Ω| → V^S×|Ω| + |Ψ|×V^S; §Perf).
        let mut min_lat = vec![f64::INFINITY; n];
        let mut digits = vec![0usize; s];
        for item in min_lat.iter_mut() {
            for o in orders {
                if let Some(l) = profile.latency_est_digits(&digits, o) {
                    if l < *item {
                        *item = l;
                    }
                }
            }
            for j in (0..s).rev() {
                digits[j] += 1;
                if digits[j] < v {
                    break;
                }
                digits[j] = 0;
            }
        }

        let mut occur = vec![vec![0usize; v]; s];
        let mut members: Vec<usize> = Vec::new();
        for slo in slo_set {
            members.clear();
            for row in occur.iter_mut() {
                row.iter_mut().for_each(|x| *x = 0);
            }
            digits.iter_mut().for_each(|d| *d = 0);
            for k in 0..n {
                if profile.acc_pred[k] >= slo.min_accuracy
                    && min_lat[k] <= slo.max_latency_ms
                {
                    members.push(k);
                    for (j, &i) in digits.iter().enumerate() {
                        occur[j][i] += 1;
                    }
                }
                for j in (0..s).rev() {
                    digits[j] += 1;
                    if digits[j] < v {
                        break;
                    }
                    digits[j] = 0;
                }
            }
            if members.is_empty() {
                continue;
            }
            let denom = members.len() as f64;
            for j in 0..s {
                for i in 0..v {
                    scores[j][i] += occur[j][i] as f64 / denom;
                }
            }
        }
        Hotness { task: profile.task.clone(), scores }
    }

    /// Positions × variants sorted by descending hotness at position j.
    pub fn ranked_at(&self, j: usize) -> Vec<(usize, f64)> {
        let mut v: Vec<(usize, f64)> = self.scores[j].iter().copied().enumerate().collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }
}

/// The preload plan: which blobs to load, per task.
#[derive(Clone, Debug, Default)]
pub struct PreloadPlan {
    /// Φᵗ — chosen (task, variant, subgraph) blobs.
    pub blobs: Vec<BlobId>,
    pub total_bytes: u64,
    pub budget_bytes: u64,
}

impl PreloadPlan {
    pub fn contains(&self, id: &BlobId) -> bool {
        self.blobs.contains(id)
    }
}

/// Bytes needed to preload *everything* (the "full preloading" reference
/// point of Fig. 14's memory-budget axis).
pub fn full_preload_bytes(tasks: &[&TaskZoo]) -> u64 {
    tasks
        .iter()
        .map(|tz| {
            tz.variants
                .iter()
                .map(|v| v.subgraphs.iter().map(|s| s.bytes).sum::<u64>())
                .sum::<u64>()
        })
        .sum()
}

/// Summary of how well a plan covers the feasible sets (diagnostics).
#[derive(Clone, Debug)]
pub struct CoverageReport {
    /// Fraction of SLO configs for which at least one fully-preloaded
    /// feasible stitched variant exists.
    pub covered_configs: f64,
}

pub fn coverage(
    profile: &TaskProfile,
    plan: &PreloadPlan,
    slo_set: &[Slo],
    orders: &[Vec<Processor>],
) -> CoverageReport {
    let mut covered = 0usize;
    let mut considered = 0usize;
    for slo in slo_set {
        let theta = algo::feasible_set(&CostModel::unit(), profile, slo, orders);
        if theta.is_empty() {
            continue; // nothing could satisfy σ even with full memory
        }
        considered += 1;
        let ok = theta.indices.iter().any(|&k| {
            let comp = profile.space.composition(k);
            comp.0.iter().enumerate().all(|(j, &i)| {
                plan.contains(&BlobId::new(&profile.task, i, j))
            })
        });
        if ok {
            covered += 1;
        }
    }
    CoverageReport {
        covered_configs: if considered == 0 {
            1.0
        } else {
            covered as f64 / considered as f64
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::memory::preload;
    use crate::profiler::{profile_task, ProfilerConfig};
    use crate::soc::latency::tests::tiny_taskzoo;
    use crate::soc::{BaseLatencies, LatencyModel, Platform};
    use crate::workload::placement_orders;
    use crate::zoo::KernelPath;

    fn setup() -> (crate::zoo::TaskZoo, TaskProfile, Vec<Vec<Processor>>) {
        let tz = tiny_taskzoo();
        let mut b = BaseLatencies::new();
        for sg in 0..2 {
            b.set("tiny", sg, KernelPath::Dense, 10.0);
            b.set("tiny", sg, KernelPath::BlockSparse, 8.0);
        }
        let plat = Platform::desktop();
        let orders = placement_orders(&plat, 2);
        let lm = LatencyModel::new(plat, b);
        let space = crate::stitching::StitchSpace::for_task(&tz);
        let oracle: Vec<f64> = space
            .iter()
            .map(|c| c.0.iter().map(|&i| tz.variants[i].accuracy).sum::<f64>() / 2.0)
            .collect();
        let cfg = ProfilerConfig {
            train_samples: 4,
            gbdt: crate::gbdt::GbdtParams {
                n_trees: 200,
                max_depth: 3,
                eta: 0.2,
                min_leaf: 1,
                subsample: 1.0,
                seed: 1,
            },
            seed: 23,
        };
        let p = profile_task(&tz, &lm, &oracle, &cfg, true);
        (tz, p, orders)
    }

    fn slos() -> Vec<Slo> {
        vec![
            Slo { min_accuracy: 0.0, max_latency_ms: 1e9 },
            Slo { min_accuracy: 0.75, max_latency_ms: 1e9 },
            Slo { min_accuracy: 0.85, max_latency_ms: 1e9 },
        ]
    }

    #[test]
    fn hotness_nonnegative_and_bounded() {
        let (_tz, p, orders) = setup();
        let h = Hotness::compute(&p, &slos(), &orders);
        for row in &h.scores {
            for &x in row {
                assert!(x >= 0.0);
                assert!(x <= slos().len() as f64 + 1e-9);
            }
        }
    }

    #[test]
    fn per_config_contributions_sum_to_one_per_position() {
        // Σ_i Occur(i)/|Θ| = 1 at each position for each σ with Θ≠∅,
        // so total score per position sums to #feasible-configs.
        let (_tz, p, orders) = setup();
        let h = Hotness::compute(&p, &slos(), &orders);
        let expected: f64 = slos()
            .iter()
            .filter(|s| !algo::feasible_set(&CostModel::unit(), &p, s, &orders).is_empty())
            .count() as f64;
        for j in 0..2 {
            let sum: f64 = h.scores[j].iter().sum();
            assert!((sum - expected).abs() < 1e-9, "pos {j}: {sum} vs {expected}");
        }
    }

    #[test]
    fn uniqueness_raises_hotness() {
        // Under the accuracy-0.85 SLO only dense-dense survives
        // (accuracies: dense 0.9, struct50 0.7 → mean ≥ 0.85 needs both
        // dense) so dense subgraphs must outscore sparse ones.
        let (_tz, p, orders) = setup();
        let h = Hotness::compute(&p, &slos(), &orders);
        for j in 0..2 {
            assert!(h.scores[j][0] > h.scores[j][1]);
        }
    }

    #[test]
    fn coverage_increases_with_budget() {
        let (tz, p, orders) = setup();
        let h = Hotness::compute(&p, &slos(), &orders);
        let full = full_preload_bytes(&[&tz]);
        let small = preload(&[(&tz, &h)], full / 10);
        let big = preload(&[(&tz, &h)], full);
        let cs = coverage(&p, &small, &slos(), &orders).covered_configs;
        let cb = coverage(&p, &big, &slos(), &orders).covered_configs;
        assert!(cb >= cs);
        assert!((cb - 1.0).abs() < 1e-9, "full budget covers everything");
    }
}
