//! Online re-planning types: what the dispatcher observes when a shard
//! saturates, and the bounded migration the planner answers with.
//!
//! The contract (`Planner::replan`) is deliberately incremental: the
//! planner never rebuilds the whole deployment mid-run. It moves **one
//! task per decision** — the hottest movable task on the saturated
//! shard — to the least-loaded shard, and re-runs variant selection
//! *only* for that task against its hotness share of the target shard's
//! memory budget. Per-task FIFO order is preserved by construction: the
//! migrated task's first query on the new shard is floored at the old
//! shard's last completion (`Session::adopt_task`).

use std::collections::BTreeMap;

use crate::optimizer::Selection;
use crate::soc::Processor;
use crate::workload::Slo;

/// The sharded deployment the planner last committed to — the `prior`
/// argument of `Planner::replan`.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Current task → shard assignment.
    pub assignment: BTreeMap<String, usize>,
    /// Number of shards (≥ 2 for replanning to be meaningful).
    pub shards: usize,
    /// The active phase's SLO configuration.
    pub slos: BTreeMap<String, Slo>,
    /// The SLO universe Ψ hotness is scored over.
    pub universe: Vec<Slo>,
}

/// What the dispatcher observed when a shard crossed its saturation
/// threshold — the `observed` argument of `Planner::replan`.
#[derive(Clone, Debug)]
pub struct ShardObservation {
    /// The shard whose backlog crossed the threshold.
    pub saturated: usize,
    /// Per-shard total backlog (ms) at observation time.
    pub shard_backlog_ms: Vec<f64>,
    /// Each shard session's committed placement order p⃗* — a migrant is
    /// re-selected against the **target's** order (a variant feasible
    /// somewhere in Ω may be unsupported or SLO-infeasible on the order
    /// the target actually serves under). A missing/empty entry falls
    /// back to the full Ω.
    pub shard_orders: Vec<Vec<Processor>>,
    /// Per-shard memory-pool capacity (bytes) — the migrant's budget
    /// share is its hotness split of the **target's** pool.
    pub shard_pool_bytes: Vec<u64>,
    /// Tasks on the saturated shard that still have queued work — the
    /// only migration candidates (moving a drained task helps nobody).
    pub movable: Vec<String>,
    /// Observed mean coalesced batch size per task (the batch hint for
    /// re-selection).
    pub mean_batch: BTreeMap<String, f64>,
    /// Telemetry's per-task arrival-rate estimates (qps). Victim
    /// scoring and the migrant's budget share weight Eq. 7 hotness by
    /// these; tasks without an estimate weigh 1.0, and an empty map
    /// reproduces pure memory-hotness scoring.
    pub arrival_qps: BTreeMap<String, f64>,
}

/// One bounded re-sharding step: move `task` from shard `from` to shard
/// `to`, serving it there with `selection` (re-chosen batch-aware under
/// the hotness budget split), or the target session's best-effort
/// fallback when `None`.
#[derive(Clone, Debug)]
pub struct Migration {
    pub task: String,
    pub from: usize,
    pub to: usize,
    pub selection: Option<Selection>,
}
