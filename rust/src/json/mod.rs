//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Offline substrate for `serde_json` (unavailable in this environment).
//! Covers the full JSON grammar the artifact manifest and config files
//! use: objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic
/// iteration (report output and config dumps are diff-stable).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders -------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    // ---- serialization --------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|b| (b as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(parse("\"héllo→\"").unwrap(), Json::Str("héllo→".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"b":[1,2.5,"x"],"a":{"k":null,"t":true}}"#;
        let v = parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse("[]").unwrap().to_string(), "[]");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn escaped_strings_roundtrip() {
        let s = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(parse(&s.to_string()).unwrap(), s);
    }

    #[test]
    fn req_reports_missing_key() {
        let v = parse("{}").unwrap();
        assert!(v.req("nope").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // Opportunistic integration check against the actual artifact.
        if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
            let m = parse(&text).expect("manifest parses");
            assert!(m.get("tasks").is_some());
        }
    }
}
