//! Profiling-cost bench (paper Table 1 / Figs. 8 & 12): wall-clock time
//! of estimator-based profiling vs the cost of exhaustive measurement,
//! with real per-run costs measured through PJRT.
//!
//! Run: `cargo bench --bench profiling_cost`

use std::time::Instant;

use sparseloom::benchkit::Bench;
use sparseloom::experiments::Ctx;
use sparseloom::profiler::cost::{CostParams, RunCosts};
use sparseloom::profiler::{profile_task, ProfilerConfig};
use sparseloom::runtime::Runtime;
use sparseloom::soc::Platform;

fn main() -> anyhow::Result<()> {
    let Ok(ctx) = Ctx::load("artifacts", false) else {
        eprintln!("no artifacts/ — run `make artifacts` first");
        return Ok(());
    };
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let task = ctx.zoo.task_names()[0].to_string();
    let tz = ctx.zoo.task(&task)?;
    let oracle = ctx.zoo.load_oracle(&task)?;

    println!("\n== estimator-based profiling (one task, V^S = {}) ==\n", oracle.len());
    Bench::header();
    let mut b = Bench::quick();
    for train in [40usize, 80, 160, 250] {
        let cfg = ProfilerConfig { train_samples: train, ..Default::default() };
        b.case(&format!("profile_task train={train}"), || {
            profile_task(tz, &lm, &oracle, &cfg, false).acc_pred.len()
        });
    }

    // Real per-run costs → projected exhaustive vs SparseLoom minutes.
    println!("\n== measured per-run costs → Fig. 12 projection ==\n");
    let rt = Runtime::new()?;
    let comp = vec![0usize; ctx.zoo.subgraphs];
    let t0 = Instant::now();
    let _ = rt.measure_accuracy(&ctx.zoo, &task, &comp)?;
    let acc_ms = t0.elapsed().as_secs_f64() * 1e3;
    let lat_ms = {
        let t0 = Instant::now();
        let _ = rt.measure_subgraph_ms(&ctx.zoo, &task, 0, tz.variants[0].spec.kernel_path, 10)?;
        t0.elapsed().as_secs_f64() * 1e3
    };
    println!("accuracy run {acc_ms:.1} ms | latency run {lat_ms:.2} ms (host PJRT)");
    let rc = RunCosts { accuracy_run_ms: acc_ms, latency_run_ms: lat_ms };
    for v in [4usize, 10] {
        let c = CostParams {
            tasks: ctx.zoo.tasks.len(),
            variants: v,
            subgraphs: ctx.zoo.subgraphs,
            processors: platform.n_processors(),
        };
        println!(
            "V={v}: exhaustive {:>8.1} min | SparseLoom {:>6.2} min | reduction {:>5.1} %",
            c.exhaustive_minutes(&rc),
            c.sparseloom_minutes(&rc),
            100.0 * (1.0 - c.sparseloom_minutes(&rc) / c.exhaustive_minutes(&rc)),
        );
    }
    Ok(())
}
