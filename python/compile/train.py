"""Synthetic task datasets + brief base-model training.

The paper's datasets (ImageNet-1K, SST-2, HAR, LibriSpeech) are
substituted with synthetic Gaussian-blob classification problems (see
DESIGN.md §Substitutions): the SLO machinery only needs each variant to
have a *genuine, distinct* accuracy, which briefly-trained tiny models
give — pruning/quantizing trained weights produces real accuracy drops
that grow with sparsity, the same structure Table 5 zoos exhibit on the
real datasets.

Datasets are class-conditional Gaussians over the task's input dimension
with class-dependent structured means (low-dimensional latent factors so
the problem is learnable but not trivial). Everything is seeded and
deterministic.
"""

from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M

N_TRAIN = 4096
N_EVAL = 512
NOISE = 5.0  # class-overlap knob: larger → lower ceiling accuracy


def make_dataset(task: str, n: int, seed: int, split: str):
    """Class-conditional Gaussian dataset for ``task``: (X f32, y int32)."""
    spec = M.TASKS[task]
    task_id = zlib.crc32(task.encode()) % (2**16)
    split_id = zlib.crc32(f"{task}/{split}".encode()) % (2**16)
    rng = np.random.default_rng(seed + split_id)
    d = spec.input_dim
    # Structured class means: rank-4 latent factors → overlapping classes.
    # Seeded by task only — train and eval share the class geometry.
    factors = np.random.default_rng(task_id).standard_normal((4, d)).astype(
        np.float32
    )
    coeffs = np.random.default_rng(task_id + 1).standard_normal(
        (M.N_CLASSES, 4)
    ).astype(np.float32)
    means = coeffs @ factors  # (classes, d)
    y = rng.integers(0, M.N_CLASSES, size=n).astype(np.int32)
    x = means[y] + NOISE * rng.standard_normal((n, d)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


def _loss_fn(task, params_flat, treedef, x, y):
    params = jax.tree_util.tree_unflatten(treedef, params_flat)
    logits = M.forward(task, x, params, path="dense", use_kernel=False)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return nll


def train_base_model(task: str, seed: int = 0, steps: int = 240,
                     batch: int = 256, lr: float = 8e-3):
    """Brief Adam training of the dense base model on synthetic data.

    Training uses the pure-jnp forward (the pallas path is export-only);
    a pytest asserts the two paths agree numerically.
    """
    params = M.init_params(task, seed)
    x_train, y_train = make_dataset(task, N_TRAIN, seed, "train")
    flat, treedef = jax.tree_util.tree_flatten(params)

    # Minimal Adam (no optax in this environment).
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    b1, b2, eps = 0.9, 0.999, 1e-8

    grad_fn = jax.jit(
        jax.grad(lambda pf, x, y: _loss_fn(task, pf, treedef, x, y)),
        static_argnums=(),
    )

    rng = np.random.default_rng(seed + 7)
    for step in range(steps):
        idx = rng.integers(0, x_train.shape[0], size=batch)
        g = grad_fn(flat, x_train[idx], y_train[idx])
        t = step + 1
        for i in range(len(flat)):
            m[i] = b1 * m[i] + (1 - b1) * g[i]
            v[i] = b2 * v[i] + (1 - b2) * g[i] ** 2
            mh = m[i] / (1 - b1**t)
            vh = v[i] / (1 - b2**t)
            flat[i] = flat[i] - lr * mh / (jnp.sqrt(vh) + eps)

    return jax.tree_util.tree_unflatten(treedef, flat)


def eval_accuracy(task: str, params, path="dense", use_kernel=False,
                  seed: int = 0, n: int = N_EVAL) -> float:
    """Top-1 accuracy on the task's held-out eval split."""
    x, y = make_dataset(task, n, seed, "eval")
    logits = M.forward(task, x, params, path=path, use_kernel=use_kernel)
    pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
    return float(jnp.mean((pred == y).astype(jnp.float32)))
