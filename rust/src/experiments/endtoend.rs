//! End-to-end experiments: Figs. 10/11 (violation rate and throughput
//! vs the six baselines across three SoCs), Figs. 15/16 (accuracy- and
//! latency-guaranteed SLOs), and the beyond-the-paper backlog study
//! (batched + sharded dispatch under bursty overload).

use std::collections::BTreeMap;

use anyhow::Result;

use super::Ctx;
use crate::baselines::Policy;
use crate::coordinator::ServeOpts;
use crate::json::Json;
use crate::metrics::{render_table, Aggregate, RunReport};
use crate::profiler::{ProfilerConfig, TaskProfile};
use crate::scenario::{
    Admission, Dispatch, PlannerConfig, Scenario, Server, ShardedServer, Sharding,
};
use crate::soc::{LatencyModel, Platform};
use crate::util::Rng;
use crate::workload::{
    accuracy_guaranteed, arrival_combinations, latency_guaranteed, slo_grid,
    Slo, TaskRanges,
};
use crate::zoo::Zoo;

/// How many arrival combinations to average over (paper: all 24; we
/// subsample deterministically to keep experiment wall-time short —
/// variance across orders is low, as the paper also notes).
const ARRIVALS: usize = 6;

/// Run all policies × platforms over a per-task SLO-set builder. Each
/// (SLO config, arrival order) pair is one closed-loop `Scenario`; the
/// server memoizes planning per config, so arrival orders reuse it.
fn policy_sweep(
    ctx: &Ctx,
    slo_builder: impl Fn(&TaskRanges) -> Vec<Slo>,
) -> Result<BTreeMap<String, BTreeMap<String, (f64, f64)>>> {
    let cfg = ProfilerConfig::default();
    let mut results: BTreeMap<String, BTreeMap<String, (f64, f64)>> = BTreeMap::new();
    for platform in Platform::all() {
        let lm = ctx.lm(platform.clone());
        let zoo = ctx.zoo_for(&platform);
        let profiles = ctx.profiles(&lm, &cfg)?;
        let tasks: Vec<String> = profiles.keys().cloned().collect();

        // Per-task SLO sets + the universe Ψ for the preloader.
        let mut grids: BTreeMap<String, Vec<Slo>> = BTreeMap::new();
        let mut universe = Vec::new();
        for name in &tasks {
            let tz = zoo.task(name)?;
            let g = slo_builder(&TaskRanges::measure(tz, &lm));
            universe.extend(g.iter().copied());
            grids.insert(name.clone(), g);
        }
        let n_cfg = grids.values().next().map(|g| g.len()).unwrap_or(0);

        let mut rng = Rng::new(7);
        let mut arrivals = arrival_combinations(&tasks);
        rng.shuffle(&mut arrivals);
        arrivals.truncate(ARRIVALS);

        for policy in Policy::all() {
            let server = Server::builder(zoo, &lm, &profiles).policy(policy).build();
            let mut agg = Aggregate::default();
            for i in 0..n_cfg {
                let slos: BTreeMap<String, Slo> = grids
                    .iter()
                    .map(|(name, g)| (name.clone(), g[i]))
                    .collect();
                for arrival in &arrivals {
                    let sc = Scenario::closed_loop(arrival, slos.clone())
                        .with_universe(universe.clone());
                    agg.push(&server.run(&sc)?);
                }
            }
            results
                .entry(platform.name.to_string())
                .or_default()
                .insert(
                    policy.name().to_string(),
                    (agg.mean_violation_pct(), agg.mean_throughput()),
                );
        }
    }
    Ok(results)
}

fn render_sweep(
    results: &BTreeMap<String, BTreeMap<String, (f64, f64)>>,
    metric: usize, // 0 = violation %, 1 = throughput
    title: &str,
    paper_note: &str,
) -> String {
    let mut out = format!("{title}\n\n");
    let policies: Vec<&str> = Policy::all().iter().map(|p| p.name()).collect();
    let mut headers = vec!["platform"];
    headers.extend(policies.iter());
    let mut rows = Vec::new();
    for (plat, by_policy) in results {
        let mut row = vec![plat.clone()];
        for p in &policies {
            let v = by_policy.get(*p).map(|x| if metric == 0 { x.0 } else { x.1 }).unwrap_or(f64::NAN);
            row.push(format!("{v:.1}"));
        }
        rows.push(row);
    }
    out.push_str(&render_table(&headers, &rows));

    // Headline improvements vs baselines.
    for (plat, by_policy) in results {
        let sl = by_policy["SparseLoom"];
        let worst_baseline = Policy::baselines()
            .iter()
            .map(|p| by_policy[p.name()])
            .fold((f64::NEG_INFINITY, f64::INFINITY), |acc, x| {
                (acc.0.max(x.0), acc.1.min(x.1))
            });
        let best_baseline = Policy::baselines()
            .iter()
            .map(|p| by_policy[p.name()])
            .fold((f64::INFINITY, f64::NEG_INFINITY), |acc, x| {
                (acc.0.min(x.0), acc.1.max(x.1))
            });
        if metric == 0 {
            out.push_str(&format!(
                "{plat}: SparseLoom {:.1} % | best baseline {:.1} % | worst {:.1} % → reduction up to {:.1} pp\n",
                sl.0, best_baseline.0, worst_baseline.0, worst_baseline.0 - sl.0,
            ));
        } else {
            out.push_str(&format!(
                "{plat}: SparseLoom {:.1} qps | best baseline {:.1} | worst {:.1} → speedup up to {:.2}x (vs best {:.2}x)\n",
                sl.1,
                best_baseline.1,
                worst_baseline.1,
                sl.1 / worst_baseline.1.max(1e-9),
                sl.1 / best_baseline.1.max(1e-9),
            ));
        }
    }
    out.push_str(paper_note);
    out.push('\n');
    out
}

/// Fig. 10: SLO violation rates, SparseLoom vs six baselines, 3 SoCs.
pub fn fig10(ctx: &Ctx) -> Result<String> {
    let results = policy_sweep(ctx, |r| slo_grid(r))?;
    Ok(render_sweep(
        &results,
        0,
        "Fig. 10 — SLO violation rate (%) across SoCs (25-config grid)",
        "[paper: SparseLoom lowest everywhere; ≤74 % reduction vs SV-LO-NP on orin,\n ≤24.7 pp vs AV-NP on desktop; SV-LO worst class]",
    ))
}

/// Fig. 11: inference throughput, SparseLoom vs six baselines, 3 SoCs.
pub fn fig11(ctx: &Ctx) -> Result<String> {
    let results = policy_sweep(ctx, |r| slo_grid(r))?;
    Ok(render_sweep(
        &results,
        1,
        "Fig. 11 — inference throughput (queries/s) across SoCs",
        "[paper: SparseLoom highest everywhere; ≤2.31x vs SV-AO-NP on laptop,\n ≤1.53x vs best baseline (SV-LO-P) on desktop; P beats NP]",
    ))
}

/// Fig. 15: accuracy-guaranteed SLOs (accuracy pinned to max).
pub fn fig15(ctx: &Ctx) -> Result<String> {
    let results = policy_sweep(ctx, |r| accuracy_guaranteed(r))?;
    Ok(render_sweep(
        &results,
        0,
        "Fig. 15 — violation rate (%) under accuracy-guaranteed SLOs",
        "[paper: SparseLoom reduces violations by up to 73.6 %]",
    ))
}

/// Fig. 16: latency-guaranteed SLOs (latency pinned to min).
pub fn fig16(ctx: &Ctx) -> Result<String> {
    let results = policy_sweep(ctx, |r| latency_guaranteed(r))?;
    Ok(render_sweep(
        &results,
        0,
        "Fig. 16 — violation rate (%) under latency-guaranteed SLOs",
        "[paper: SparseLoom reduces violations by up to 68.2 %]",
    ))
}

/// Backlog study (beyond the paper): bursty overload served by the
/// single-server unbatched baseline vs batched and/or sharded dispatch,
/// at the default 6 s stream horizon (`exp all` / `exp backlog`).
pub fn backlog(ctx: &Ctx) -> Result<String> {
    backlog_with(ctx, 6_000.0)
}

/// [`backlog`] at an explicit stream horizon — `exp backlog
/// --horizon-ms N` routes here on the artifacts path too, so the flag
/// is never silently ignored.
pub fn backlog_with(ctx: &Ctx, horizon_ms: f64) -> Result<String> {
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let zoo = ctx.zoo_for(&platform);
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;
    backlog_comparison(zoo, &lm, &profiles, horizon_ms)
}

/// [`backlog_with`]'s machine-readable twin (`exp backlog --json`):
/// per-arm full [`crate::metrics::ShardedReport`] JSON instead of the
/// text tables.
pub fn backlog_json_with(ctx: &Ctx, horizon_ms: f64) -> Result<Json> {
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let zoo = ctx.zoo_for(&platform);
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;
    backlog_comparison_json(zoo, &lm, &profiles, horizon_ms)
}

/// Text rendering of the backlog study (the default `exp backlog`).
pub fn backlog_comparison(
    zoo: &Zoo,
    lm: &LatencyModel,
    profiles: &BTreeMap<String, TaskProfile>,
    horizon_ms: f64,
) -> Result<String> {
    Ok(backlog_study(zoo, lm, profiles, horizon_ms)?.0)
}

/// JSON rendering of the backlog study (`exp backlog --json`, fixture
/// path included): `{horizon_ms, arms: [{config, report}, ...]}` with
/// each arm's full sharded report.
pub fn backlog_comparison_json(
    zoo: &Zoo,
    lm: &LatencyModel,
    profiles: &BTreeMap<String, TaskProfile>,
    horizon_ms: f64,
) -> Result<Json> {
    Ok(backlog_study(zoo, lm, profiles, horizon_ms)?.1)
}

/// Core of the backlog study, parameterized over the zoo (so
/// `benches/dispatch_backlog.rs` can run it on the synthetic fixture
/// when `artifacts/` is absent) and the stream horizon (so the CI
/// smoke stage can run a tiny hermetic instance via
/// `exp backlog --fixture --horizon-ms ...`). Rates are derived from
/// the measured per-task latency ranges: bursts demand ~4× the
/// pipeline's capacity, the base load ~25 %. Returns the text report
/// and its structured JSON twin, built from the same runs.
fn backlog_study(
    zoo: &Zoo,
    lm: &LatencyModel,
    profiles: &BTreeMap<String, TaskProfile>,
    horizon_ms: f64,
) -> Result<(String, Json)> {
    let tasks: Vec<String> = profiles.keys().cloned().collect();
    let mut slos: BTreeMap<String, Slo> = BTreeMap::new();
    let mut universe = Vec::new();
    let mut lat_sum = 0.0;
    for name in &tasks {
        let ranges = TaskRanges::measure(zoo.task(name)?, lm);
        lat_sum += ranges.lat_min_ms;
        let grid = slo_grid(&ranges);
        universe.extend(grid.iter().copied());
        slos.insert(name.clone(), grid[12]);
    }
    let mean_lat = (lat_sum / tasks.len() as f64).max(1e-6);
    let per_task = tasks.len() as f64;
    let base_qps = 250.0 / mean_lat / per_task;
    let burst_qps = 4_000.0 / mean_lat / per_task;

    let base = Scenario::bursty(&tasks, slos, base_qps, burst_qps, 500.0, horizon_ms.max(500.0))
        .with_name("backlog")
        .with_seed(11)
        .with_universe(universe)
        .with_admission(Admission::Deadline { slack: 2.0 });

    let deadline = Admission::Deadline { slack: 2.0 };
    let configs: Vec<(&str, usize, usize, Admission, PlannerConfig)> = vec![
        ("1 shard, unbatched", 1, 1, deadline.clone(), PlannerConfig::default()),
        ("1 shard, batch<=4", 1, 4, deadline.clone(), PlannerConfig::default()),
        ("2 shards, unbatched", 2, 1, deadline.clone(), PlannerConfig::default()),
        ("2 shards, batch<=4", 2, 4, deadline.clone(), PlannerConfig::default()),
        (
            "2 shards, batch<=4, fair",
            2,
            4,
            Admission::Fair { slack: 2.0, weights: BTreeMap::new() },
            PlannerConfig::default(),
        ),
        // The planner arm: batch-aware Algorithm 1 + online re-planning
        // (hottest task migrates off a saturated shard, per-task FIFO
        // preserved, budgets split by traffic-weighted hotness).
        (
            "2 shards, batch<=4, replan",
            2,
            4,
            deadline.clone(),
            PlannerConfig::replanning(),
        ),
        // Telemetry-driven query-level work stealing, no whole-task
        // migration.
        (
            "2 shards, batch<=4, steal",
            2,
            4,
            deadline.clone(),
            PlannerConfig::stealing(),
        ),
        // The full online stack: replan + steal + warm migration (pool
        // contents travel with the migrant — no cold recompiles).
        (
            "2 shards, batch<=4, steal+warm",
            2,
            4,
            deadline,
            PlannerConfig::online(),
        ),
        // The predictive arm: forecast-driven admission (shed on
        // projected queueing, before deadline slack is exhausted) plus
        // forecast-triggered replan/steal/warm-migration.
        (
            "2 shards, batch<=4, predictive",
            2,
            4,
            Admission::Predictive { horizon_ms: 100.0, headroom: 2.0 },
            PlannerConfig::predictive(),
        ),
    ];
    let mut rows = Vec::new();
    let mut arms = Vec::new();
    let mut baseline: Option<RunReport> = None;
    let mut static_sharded: Option<RunReport> = None;
    let mut fair_arm: Option<RunReport> = None;
    let mut replanned: Option<RunReport> = None;
    let mut steal_warm: Option<RunReport> = None;
    let mut predictive: Option<RunReport> = None;
    let mut predictive_forecast: BTreeMap<String, f64> = BTreeMap::new();
    let mut steal_warm_rates: BTreeMap<String, f64> = BTreeMap::new();
    for (label, shards, max_batch, admission, planner) in configs {
        let opts = if planner.batch_aware {
            // Batch-aware Algorithm 1 at the dispatch operating point.
            ServeOpts { batch_hint: max_batch.max(1) as f64, ..Default::default() }
        } else {
            ServeOpts::default()
        };
        let sc = base
            .clone()
            .with_admission(admission)
            .with_dispatch(Dispatch::batched(max_batch))
            .with_sharding(Sharding::hash(shards))
            .with_planner(planner);
        let sharded = ShardedServer::build(zoo, lm, profiles, opts, sc.sharding.clone())?;
        let full = sharded.run(&sc)?;
        let mean_util = if full.budget_utilization.is_empty() {
            0.0
        } else {
            full.budget_utilization.iter().sum::<f64>()
                / full.budget_utilization.len() as f64
        };
        arms.push(Json::obj(vec![
            ("config", Json::Str(label.to_string())),
            ("report", full.to_json()),
        ]));
        let report = full.aggregate;
        rows.push(vec![
            label.to_string(),
            format!("{}", report.total_queries),
            format!("{}", report.total_dropped),
            format!("{}", report.slo_misses()),
            format!("{:.1}", 100.0 * report.violation_rate()),
            format!("{:.1}", report.throughput_qps()),
            format!("{:.2}", report.mean_batch_size()),
            format!("{:.3}", report.fairness_index()),
            format!("{}", full.migrations),
            format!("{}", full.steals),
            format!("{}", report.recoveries.len()),
            format!("{:.0}", report.throttled_ms),
            format!("{}", report.cold_compiles),
            format!("{:.0}%", 100.0 * mean_util),
            format!("{:.0}", report.makespan_ms),
        ]);
        if baseline.is_none() {
            baseline = Some(report.clone());
        }
        if label == "2 shards, batch<=4" {
            static_sharded = Some(report.clone());
        }
        if label == "2 shards, batch<=4, fair" {
            fair_arm = Some(report.clone());
        }
        if label == "2 shards, batch<=4, replan" {
            replanned = Some(report.clone());
        }
        if label == "2 shards, batch<=4, steal+warm" {
            steal_warm = Some(report.clone());
            steal_warm_rates = full.arrival_est_qps.clone();
        }
        if label == "2 shards, batch<=4, predictive" {
            predictive_forecast = report.slo_forecast.clone();
            predictive = Some(report);
        }
    }
    let mut out = String::from(
        "Backlog — bursty overload: single server vs batched/sharded/replanned/\
         stolen/predictive dispatch\n\n",
    );
    out.push_str(&render_table(
        &[
            "config", "done", "dropped", "miss", "viol%", "qps", "batch",
            "fairness", "mig", "steal", "recov", "thrott", "coldc", "util",
            "makespan",
        ],
        &rows,
    ));
    let (b, s) = (baseline.unwrap(), static_sharded.unwrap());
    out.push_str(&format!(
        "\n2 shards × batch 4 vs baseline: completed {} vs {} ({:+}), \
         dropped {} vs {} ({:+})\n",
        s.total_queries,
        b.total_queries,
        s.total_queries as i64 - b.total_queries as i64,
        s.total_dropped,
        b.total_dropped,
        s.total_dropped as i64 - b.total_dropped as i64,
    ));
    let r = replanned.unwrap();
    out.push_str(&format!(
        "replan vs static sharding: completed {} vs {} ({:+}), \
         dropped {} vs {} ({:+})\n",
        r.total_queries,
        s.total_queries,
        r.total_queries as i64 - s.total_queries as i64,
        r.total_dropped,
        s.total_dropped,
        r.total_dropped as i64 - s.total_dropped as i64,
    ));
    let w = steal_warm.unwrap();
    out.push_str(&format!(
        "steal+warm vs replan: completed {} vs {} ({:+}), dropped {} vs {} ({:+}), \
         cold compiles {} vs {}\n",
        w.total_queries,
        r.total_queries,
        w.total_queries as i64 - r.total_queries as i64,
        w.total_dropped,
        r.total_dropped,
        w.total_dropped as i64 - r.total_dropped as i64,
        w.cold_compiles,
        r.cold_compiles,
    ));
    let (f, p) = (fair_arm.unwrap(), predictive.unwrap());
    out.push_str(&format!(
        "predictive vs reactive fair: completed {} vs {} ({:+}), \
         dropped {} vs {} ({:+}), per-request SLO misses {} vs {}\n",
        p.total_queries,
        f.total_queries,
        p.total_queries as i64 - f.total_queries as i64,
        p.total_dropped,
        f.total_dropped,
        p.total_dropped as i64 - f.total_dropped as i64,
        p.slo_misses(),
        f.slo_misses(),
    ));

    // The predictive arm's per-task SLO forecast: projected violation
    // rate over the next horizon (observed miss share × forecast load).
    let mut forecast_rows = Vec::new();
    for task in &tasks {
        forecast_rows.push(vec![
            task.clone(),
            predictive_forecast
                .get(task)
                .map(|p| format!("{:.0}%", 100.0 * p))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    out.push_str("\nper-task SLO violation forecast (predictive arm)\n");
    out.push_str(&render_table(&["task", "forecast"], &forecast_rows));

    // Telemetry quality: estimated vs true mean arrival rate per task
    // (a square-wave bursty stream spends half of each period at each
    // rate, so the true mean is (base + burst) / 2; the EWMA is
    // unit-tested to land within 25 % on the Poisson fixture).
    let true_qps = 0.5 * (base_qps + burst_qps);
    let mut rate_rows = Vec::new();
    for task in &tasks {
        let est = steal_warm_rates.get(task).copied();
        rate_rows.push(vec![
            task.clone(),
            format!("{true_qps:.2}"),
            est.map(|e| format!("{e:.2}")).unwrap_or_else(|| "-".into()),
            est.map(|e| format!("{:+.0}%", 100.0 * (e - true_qps) / true_qps))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    out.push_str("\narrival-rate telemetry (steal+warm arm): estimated vs true\n");
    out.push_str(&render_table(&["task", "true qps", "ewma qps", "err"], &rate_rows));
    let doc = Json::obj(vec![
        ("study", Json::Str("backlog".to_string())),
        ("horizon_ms", Json::Num(horizon_ms)),
        ("arms", Json::Arr(arms)),
    ]);
    Ok((out, doc))
}
