//! End-to-end serving bench (paper Figs. 10/11): wall-clock cost of the
//! full plan→preload→serve cycle per policy on the desktop profile,
//! plus the real-PJRT serving loop (every query executes a real chain).
//!
//! Run: `cargo bench --bench end_to_end`

use std::collections::BTreeMap;
use std::time::Instant;

use sparseloom::baselines::Policy;
use sparseloom::benchkit::Bench;
use sparseloom::experiments::Ctx;
use sparseloom::profiler::ProfilerConfig;
use sparseloom::runtime::Runtime;
use sparseloom::scenario::{Scenario, Server};
use sparseloom::soc::Platform;
use sparseloom::workload::{slo_grid, Slo, TaskRanges};

fn main() -> anyhow::Result<()> {
    let Ok(ctx) = Ctx::load("artifacts", false) else {
        eprintln!("no artifacts/ — run `make artifacts` first");
        return Ok(());
    };
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;

    let mut grids: BTreeMap<String, Vec<Slo>> = BTreeMap::new();
    let mut universe = Vec::new();
    for (name, tz) in &ctx.zoo.tasks {
        let g = slo_grid(&TaskRanges::measure(tz, &lm));
        universe.extend(g.iter().copied());
        grids.insert(name.clone(), g);
    }
    let slos: BTreeMap<String, Slo> =
        grids.iter().map(|(n, g)| (n.clone(), g[12])).collect();
    let arrival: Vec<String> = profiles.keys().cloned().collect();

    println!("\n== plan + serve cycle per policy (desktop, 4×100 queries, sim timing) ==\n");
    Bench::header();
    let mut b = Bench::quick();
    let scenario = Scenario::closed_loop(&arrival, slos.clone())
        .with_universe(universe.clone());
    for policy in Policy::all() {
        // A fresh server per iteration so the cycle includes planning.
        b.case(&format!("cycle {}", policy.name()), || {
            let server = Server::builder(&ctx.zoo, &lm, &profiles)
                .policy(policy)
                .build();
            server.run(&scenario).unwrap().total_queries
        });
    }

    // Real PJRT serving: run the selected stitched chain for every query.
    println!("\n== real-PJRT serving loop (SparseLoom selection, 4 tasks × 50 queries) ==\n");
    let rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping real-PJRT loop: {e:#}");
            return Ok(());
        }
    };
    let server = Server::builder(&ctx.zoo, &lm, &profiles).build();
    let prepared = server.prepare(&slos, &universe)?;
    // Warm executables + weights.
    let mut inputs = BTreeMap::new();
    for (name, sel) in &prepared.selections {
        if let Some(sel) = sel {
            let tz = ctx.zoo.task(name)?;
            let comp = profiles[name].space.composition(sel.stitched_index);
            let input: Vec<f32> =
                (0..tz.input_dim).map(|i| (i as f32 * 0.37).cos()).collect();
            let _ = rt.run_chain(&ctx.zoo, name, &comp.0, 1, &input)?;
            inputs.insert(name.clone(), (comp, input));
        }
    }
    let t0 = Instant::now();
    let mut served = 0usize;
    for _ in 0..50 {
        for (name, (comp, input)) in &inputs {
            let _ = rt.run_chain(&ctx.zoo, name, &comp.0, 1, input)?;
            served += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {served} real queries in {dt:.3} s → {:.0} q/s on host PJRT-CPU",
        served as f64 / dt
    );
    Ok(())
}
