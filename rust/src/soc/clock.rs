//! Discrete-event simulation clock for the heterogeneous SoC.
//!
//! Real compute runs through PJRT on the host CPU; *device timing* is
//! simulated: every subgraph execution is booked onto its processor's
//! timeline at the latency the platform model predicts. Throughput and
//! SLO metrics are then read off virtual time, which preserves the
//! heterogeneous timing structure the paper's scheduler exploits.

use std::collections::BTreeMap;

use super::profile::Processor;

/// Per-processor occupancy timeline.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub busy_until_ms: f64,
    pub total_busy_ms: f64,
    pub jobs: u64,
}

/// Virtual-time engine: FIFO, non-preemptive per processor.
#[derive(Clone, Debug)]
pub struct SocSim {
    timelines: BTreeMap<Processor, Timeline>,
    /// Latest event end time seen (the virtual "now").
    pub horizon_ms: f64,
    /// DVFS-style thermal throttle: sorted `(busy_ms, factor)` steps.
    /// Once a processor's accumulated busy time reaches `busy_ms`, its
    /// bookings are stretched by `factor` (see the fault lab,
    /// `scenario::faults::ThrottleCurve`). Empty ⇒ bookings are exact —
    /// the pre-fault-lab behavior, bit for bit.
    throttle: Vec<(f64, f64)>,
    /// Extra virtual time bookings have paid to throttling so far.
    throttled_ms: f64,
}

impl SocSim {
    pub fn new(processors: &[Processor]) -> Self {
        Self {
            timelines: processors.iter().map(|&p| (p, Timeline::default())).collect(),
            horizon_ms: 0.0,
            throttle: Vec::new(),
            throttled_ms: 0.0,
        }
    }

    /// Install a thermal throttle curve as `(busy_ms, factor)` steps
    /// (must be sorted by `busy_ms`; factor 1 applies before the first
    /// step). An empty curve restores exact booking.
    pub fn set_throttle(&mut self, steps: Vec<(f64, f64)>) {
        self.throttle = steps;
    }

    /// The slowdown factor in effect for a processor that has already
    /// accumulated `busy_ms` of work.
    fn throttle_factor(&self, busy_ms: f64) -> f64 {
        let mut f = 1.0;
        for &(at, factor) in &self.throttle {
            if busy_ms >= at {
                f = factor;
            } else {
                break;
            }
        }
        f
    }

    /// Total extra virtual time paid to thermal throttling.
    pub fn throttled_ms(&self) -> f64 {
        self.throttled_ms
    }

    /// Book `dur_ms` of work on `proc`, not starting before `ready_ms`.
    /// Returns (start, end) in virtual ms. With a throttle curve
    /// installed, the booked duration is stretched by the factor the
    /// processor's accumulated busy time has reached — the thermal
    /// governor has dropped the clock.
    pub fn book(&mut self, proc: Processor, ready_ms: f64, dur_ms: f64) -> (f64, f64) {
        let throttled = if self.throttle.is_empty() {
            dur_ms
        } else {
            let busy = self
                .timelines
                .get(&proc)
                .map(|t| t.total_busy_ms)
                .unwrap_or(0.0);
            dur_ms * self.throttle_factor(busy)
        };
        self.throttled_ms += throttled - dur_ms;
        let t = self
            .timelines
            .get_mut(&proc)
            .unwrap_or_else(|| panic!("processor {proc:?} not on this platform"));
        let start = ready_ms.max(t.busy_until_ms);
        let end = start + throttled;
        t.busy_until_ms = end;
        t.total_busy_ms += throttled;
        t.jobs += 1;
        if end > self.horizon_ms {
            self.horizon_ms = end;
        }
        (start, end)
    }

    /// Earliest time `proc` could start new work.
    pub fn available_at(&self, proc: Processor) -> f64 {
        self.timelines[&proc].busy_until_ms
    }

    pub fn timeline(&self, proc: Processor) -> &Timeline {
        &self.timelines[&proc]
    }

    /// Utilization of each processor over the busy horizon.
    pub fn utilization(&self) -> BTreeMap<Processor, f64> {
        let h = self.horizon_ms.max(1e-9);
        self.timelines
            .iter()
            .map(|(&p, t)| (p, t.total_busy_ms / h))
            .collect()
    }

    pub fn reset(&mut self) {
        for t in self.timelines.values_mut() {
            *t = Timeline::default();
        }
        self.horizon_ms = 0.0;
        self.throttled_ms = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use Processor::*;

    #[test]
    fn fifo_serialization_on_one_processor() {
        let mut sim = SocSim::new(&[Cpu, Gpu]);
        let (s1, e1) = sim.book(Cpu, 0.0, 10.0);
        let (s2, e2) = sim.book(Cpu, 0.0, 5.0);
        assert_eq!((s1, e1), (0.0, 10.0));
        assert_eq!((s2, e2), (10.0, 15.0)); // queued behind job 1
        assert_eq!(sim.horizon_ms, 15.0);
    }

    #[test]
    fn parallel_processors_overlap() {
        let mut sim = SocSim::new(&[Cpu, Gpu]);
        sim.book(Cpu, 0.0, 10.0);
        let (s, e) = sim.book(Gpu, 0.0, 4.0);
        assert_eq!((s, e), (0.0, 4.0));
    }

    #[test]
    fn ready_time_respected() {
        let mut sim = SocSim::new(&[Cpu]);
        let (s, _) = sim.book(Cpu, 7.5, 1.0);
        assert_eq!(s, 7.5);
    }

    #[test]
    fn monotone_horizon_and_utilization() {
        let mut sim = SocSim::new(&[Cpu, Gpu]);
        sim.book(Cpu, 0.0, 8.0);
        sim.book(Gpu, 2.0, 8.0);
        let u = sim.utilization();
        assert!((u[&Cpu] - 0.8).abs() < 1e-9);
        assert!((u[&Gpu] - 0.8).abs() < 1e-9);
        assert_eq!(sim.horizon_ms, 10.0);
    }

    #[test]
    fn reset_clears_state() {
        let mut sim = SocSim::new(&[Cpu]);
        sim.book(Cpu, 0.0, 3.0);
        sim.reset();
        assert_eq!(sim.horizon_ms, 0.0);
        assert_eq!(sim.available_at(Cpu), 0.0);
    }

    #[test]
    #[should_panic]
    fn unknown_processor_panics() {
        let mut sim = SocSim::new(&[Cpu]);
        sim.book(Npu, 0.0, 1.0);
    }

    #[test]
    fn throttle_stretches_bookings_past_busy_thresholds() {
        let mut sim = SocSim::new(&[Cpu, Gpu]);
        sim.set_throttle(vec![(10.0, 2.0)]);
        // Below the threshold: exact booking.
        let (s, e) = sim.book(Cpu, 0.0, 10.0);
        assert_eq!((s, e), (0.0, 10.0));
        assert_eq!(sim.throttled_ms(), 0.0);
        // At 10 ms accumulated busy time the governor halves the clock.
        let (s, e) = sim.book(Cpu, 0.0, 5.0);
        assert_eq!((s, e), (10.0, 20.0));
        assert_eq!(sim.throttled_ms(), 5.0);
        // Busy time is per processor: a cold Gpu is unthrottled.
        let (s, e) = sim.book(Gpu, 0.0, 5.0);
        assert_eq!((s, e), (0.0, 5.0));
        assert_eq!(sim.throttled_ms(), 5.0);
    }

    #[test]
    fn empty_throttle_is_bit_identical_to_no_throttle() {
        let mut plain = SocSim::new(&[Cpu]);
        let mut curved = SocSim::new(&[Cpu]);
        curved.set_throttle(Vec::new());
        for (ready, dur) in [(0.0, 3.7), (1.2, 0.9), (10.0, 2.3)] {
            let a = plain.book(Cpu, ready, dur);
            let b = curved.book(Cpu, ready, dur);
            assert_eq!(a.0.to_bits(), b.0.to_bits());
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }
        assert_eq!(curved.throttled_ms(), 0.0);
    }

    #[test]
    fn reset_clears_throttle_debt_but_keeps_curve() {
        let mut sim = SocSim::new(&[Cpu]);
        sim.set_throttle(vec![(0.0, 3.0)]);
        sim.book(Cpu, 0.0, 2.0);
        assert_eq!(sim.throttled_ms(), 4.0);
        sim.reset();
        assert_eq!(sim.throttled_ms(), 0.0);
        let (_, e) = sim.book(Cpu, 0.0, 1.0);
        assert_eq!(e, 3.0, "the installed curve still applies after reset");
    }
}
