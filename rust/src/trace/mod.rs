//! Deterministic structured tracing: request-lifecycle spans and
//! control-plane audit events for every serving run.
//!
//! Two event families share one record shape ([`TraceEvent`]):
//!
//! * **`TR-REQ-*`** — the life of one query: arrive → admit/shed →
//!   queue → execute → done/drop, stamped with shard, task, request id,
//!   and virtual-time begin/end. The `TR-REQ-EXEC` span carries the
//!   full latency decomposition (`service_ms`, the cold/warm/link
//!   penalty split, throttle stretch, batch id and size) that
//!   [`explain`] attributes SLO violations with.
//! * **`TR-CTL-*`** — control-plane decisions: plan, steal, replan,
//!   warm migration (with link cost), crash redirect, crash/recover,
//!   throttle debt. Each carries the inputs that drove the decision
//!   (observed vs forecast backlog, the saturation threshold, the
//!   remaining migration budget), so every adaptive move is auditable.
//!
//! Everything is a pure function of virtual time — no RNG, no wall
//! clock — so the same scenario + seed yields a byte-identical trace.
//! Determinism across the threaded drives comes from the same argument
//! as the metrics merge: per-shard events live in session-local sinks
//! that only their own shard thread touches, are drained in
//! shard-index order at phase end, and control events are emitted only
//! from coordinator-sequential code. [`canonical`] then stable-sorts
//! the concatenation by `begin_ms`, which preserves the (identical)
//! shard-order tie-break — so threaded and sequential runs produce the
//! same bytes, and the file is globally time-sorted (the `SL-TRC-003`
//! monotonicity lint holds by construction).
//!
//! Emission goes through the cheap [`TraceSink`] trait: [`NoopSink`]
//! by default (disabled tracing retains zero events and perturbs
//! nothing), [`RingSink`] when `ServeOpts::trace` is set.

pub mod explain;
pub mod export;

use std::fmt::Write as _;

use crate::analysis::{Diagnostic, Report};
use crate::json::{self, Json};

// ---- reason codes ------------------------------------------------------

/// Query entered the system (point, effective arrival).
pub const TR_REQ_ARRIVE: &str = "TR-REQ-ARRIVE";
/// Admission accepted the query (point; args: observed backlog).
pub const TR_REQ_ADMIT: &str = "TR-REQ-ADMIT";
/// Admission shed the query (point; args: observed backlog, projected
/// growth, the headroom budget it exceeded).
pub const TR_REQ_SHED: &str = "TR-REQ-SHED";
/// Query dropped outside admission (args: `cause` — 1 crash-swallowed,
/// 2 no runnable variant, 3 unsupported processor).
pub const TR_REQ_DROP: &str = "TR-REQ-DROP";
/// Queueing span: effective arrival → first stage start.
pub const TR_REQ_QUEUE: &str = "TR-REQ-QUEUE";
/// Execution span: first stage start → batch completion. Carries the
/// full latency decomposition (see [`explain`]).
pub const TR_REQ_EXEC: &str = "TR-REQ-EXEC";
/// Query completed (point at the execution span's end).
pub const TR_REQ_DONE: &str = "TR-REQ-DONE";

/// Session opened with its planned placement (point at virtual 0).
pub const TR_CTL_PLAN: &str = "TR-CTL-PLAN";
/// A saturated shard's batch was served by a thief shard.
pub const TR_CTL_STEAL: &str = "TR-CTL-STEAL";
/// The planner chose a migration victim (decision inputs attached).
pub const TR_CTL_REPLAN: &str = "TR-CTL-REPLAN";
/// A task was adopted by another shard (steal bootstrap, crash
/// redirect, or replan), with its warm-payload size and link cost.
pub const TR_CTL_MIGRATE: &str = "TR-CTL-MIGRATE";
/// A batch headed for a crashed shard was rerouted to a live one.
pub const TR_CTL_REDIRECT: &str = "TR-CTL-REDIRECT";
/// Crash window span (from the fault profile, per shard).
pub const TR_CTL_CRASH: &str = "TR-CTL-CRASH";
/// First completion after a crash rejoin (args: recovery latency).
pub const TR_CTL_RECOVER: &str = "TR-CTL-RECOVER";
/// A batch paid DVFS throttle stretch (args: extra booked ms).
pub const TR_CTL_THROTTLE: &str = "TR-CTL-THROTTLE";
/// The planner synthesized (or re-used from cache) a stitched variant
/// under SLO/budget pressure and committed the switch. Args carry the
/// decision inputs: forecast/threshold backlog, pool utilization,
/// search stats (expanded/evaluated/cache_hit), old/new stitched index
/// and estimated latency, and the paid switch penalty.
pub const TR_CTL_SYNTH: &str = "TR-CTL-SYNTH";

/// Every reason code this crate emits — the registry `SL-TRC-002`
/// checks unknown codes against. Append-only.
pub const KNOWN_CODES: &[&str] = &[
    TR_REQ_ARRIVE,
    TR_REQ_ADMIT,
    TR_REQ_SHED,
    TR_REQ_DROP,
    TR_REQ_QUEUE,
    TR_REQ_EXEC,
    TR_REQ_DONE,
    TR_CTL_PLAN,
    TR_CTL_STEAL,
    TR_CTL_REPLAN,
    TR_CTL_MIGRATE,
    TR_CTL_REDIRECT,
    TR_CTL_CRASH,
    TR_CTL_RECOVER,
    TR_CTL_THROTTLE,
    TR_CTL_SYNTH,
];

/// `TR-REQ-DROP` cause argument: crash window swallowed the query.
pub const DROP_CAUSE_CRASH: f64 = 1.0;
/// `TR-REQ-DROP` cause argument: the task has no runnable variant.
pub const DROP_CAUSE_NO_VARIANT: f64 = 2.0;
/// `TR-REQ-DROP` cause argument: variant unsupported on its processor.
pub const DROP_CAUSE_UNSUPPORTED: f64 = 3.0;

// ---- the event record --------------------------------------------------

/// One trace record. Points have `begin_ms == end_ms`; spans have
/// `end_ms >= begin_ms`. `args` hold the numeric decision inputs /
/// latency decomposition, in emission order (serialization sorts keys,
/// so the on-disk form is order-independent anyway).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub code: String,
    /// True (fleet-level) shard index — sessions are re-stamped by the
    /// sharded drives, which see the real topology.
    pub shard: usize,
    /// Task the event concerns (empty for shard-level events).
    pub task: String,
    /// Request id for `TR-REQ-*` events; `None` for control events.
    pub id: Option<u64>,
    pub begin_ms: f64,
    pub end_ms: f64,
    pub args: Vec<(String, f64)>,
}

impl TraceEvent {
    /// Build an event; `args` keys are static for cheap emission.
    pub fn new(
        code: &str,
        shard: usize,
        task: &str,
        id: Option<u64>,
        begin_ms: f64,
        end_ms: f64,
        args: &[(&str, f64)],
    ) -> TraceEvent {
        TraceEvent {
            code: code.to_string(),
            shard,
            task: task.to_string(),
            id,
            begin_ms,
            end_ms,
            args: args.iter().map(|&(k, v)| (k.to_string(), v)).collect(),
        }
    }

    /// Look up a named argument.
    pub fn arg(&self, key: &str) -> Option<f64> {
        self.args.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    }

    /// One JSON object (the JSONL line payload).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("code", Json::Str(self.code.clone())),
            ("shard", Json::Num(self.shard as f64)),
            ("task", Json::Str(self.task.clone())),
            ("begin_ms", Json::Num(self.begin_ms)),
            ("end_ms", Json::Num(self.end_ms)),
            (
                "args",
                Json::Obj(
                    self.args
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ),
        ];
        if let Some(id) = self.id {
            fields.push(("id", Json::Num(id as f64)));
        }
        Json::obj(fields)
    }

    /// Parse one JSONL object back into an event.
    pub fn from_json(v: &Json) -> Result<TraceEvent, String> {
        let code = v
            .get("code")
            .and_then(|c| c.as_str())
            .ok_or("missing string field \"code\"")?
            .to_string();
        let shard = v
            .get("shard")
            .and_then(|s| s.as_usize())
            .ok_or("missing integer field \"shard\"")?;
        let task = v
            .get("task")
            .and_then(|t| t.as_str())
            .ok_or("missing string field \"task\"")?
            .to_string();
        let begin_ms = v
            .get("begin_ms")
            .and_then(|b| b.as_f64())
            .ok_or("missing number field \"begin_ms\"")?;
        let end_ms = v
            .get("end_ms")
            .and_then(|e| e.as_f64())
            .ok_or("missing number field \"end_ms\"")?;
        let id = v.get("id").and_then(|i| i.as_u64());
        let mut args = Vec::new();
        if let Some(obj) = v.get("args").and_then(|a| a.as_obj()) {
            for (k, val) in obj {
                let n = val
                    .as_f64()
                    .ok_or_else(|| format!("non-numeric arg {k:?}"))?;
                args.push((k.clone(), n));
            }
        }
        Ok(TraceEvent { code, shard, task, id, begin_ms, end_ms, args })
    }
}

// ---- sinks -------------------------------------------------------------

/// Where sessions put events. The trait is deliberately tiny so the
/// disabled path costs one virtual call on a `bool` check per batch.
pub trait TraceSink: Send {
    /// Whether emission is on — callers skip building event args when
    /// this is false.
    fn enabled(&self) -> bool;
    fn emit(&mut self, ev: TraceEvent);
    /// Take everything recorded so far (drained at session finish).
    fn drain(&mut self) -> Vec<TraceEvent>;
}

/// The default sink: records nothing, allocates nothing.
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn enabled(&self) -> bool {
        false
    }
    fn emit(&mut self, _ev: TraceEvent) {}
    fn drain(&mut self) -> Vec<TraceEvent> {
        Vec::new()
    }
}

/// In-memory buffering sink (unbounded; traces are opt-in and runs are
/// finite virtual horizons).
#[derive(Default)]
pub struct RingSink {
    events: Vec<TraceEvent>,
}

impl TraceSink for RingSink {
    fn enabled(&self) -> bool {
        true
    }
    fn emit(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }
    fn drain(&mut self) -> Vec<TraceEvent> {
        std::mem::take(&mut self.events)
    }
}

/// The sink `ServeOpts::trace` selects.
pub fn sink_for(enabled: bool) -> Box<dyn TraceSink> {
    if enabled {
        Box::new(RingSink::default())
    } else {
        Box::new(NoopSink)
    }
}

// ---- canonical assembly ------------------------------------------------

/// Canonicalize a trace: stable sort by `begin_ms` (IEEE total order).
/// The input concatenation (shard-index order, control events last) is
/// identical for threaded and sequential runs, and a stable sort
/// preserves that order among ties — so the canonical trace is
/// bit-identical across drive modes *and* globally time-sorted.
pub fn canonical(mut events: Vec<TraceEvent>) -> Vec<TraceEvent> {
    events.sort_by(|a, b| a.begin_ms.total_cmp(&b.begin_ms));
    events
}

// ---- JSON Lines export / import ---------------------------------------

/// Serialize a trace as JSON Lines: one compact JSON object per event,
/// in trace order.
pub fn to_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        let _ = writeln!(out, "{}", ev.to_json());
    }
    out
}

/// Parse a JSONL trace, collecting `SL-TRC-*` diagnostics:
///
/// * `SL-TRC-001` (error) — empty/truncated file or a malformed line
///   (a partially-written trace cut mid-object parses as this).
/// * `SL-TRC-002` (warn) — a reason code outside [`KNOWN_CODES`]
///   (a newer writer, or a hand-edited file); the event is kept.
/// * `SL-TRC-003` (error) — virtual time runs backwards (`begin_ms`
///   not monotone non-decreasing); canonical traces are time-sorted,
///   so this only fires on corrupted or re-ordered files.
///
/// Events that parsed are returned even when diagnostics fired, so
/// callers can decide severity via [`Report::fail_on_errors`].
pub fn parse_jsonl(text: &str) -> (Vec<TraceEvent>, Report) {
    let mut report = Report::default();
    let mut events = Vec::new();
    let mut last_begin = f64::NEG_INFINITY;
    let mut any_line = false;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        any_line = true;
        let at = format!("line {}", lineno + 1);
        let parsed = match json::parse(line) {
            Ok(v) => v,
            Err(e) => {
                report.push(Diagnostic::error(
                    "SL-TRC-001",
                    at.as_str(),
                    format!("truncated or malformed trace line: {e}"),
                ));
                continue;
            }
        };
        let ev = match TraceEvent::from_json(&parsed) {
            Ok(ev) => ev,
            Err(e) => {
                report.push(Diagnostic::error(
                    "SL-TRC-001",
                    at.as_str(),
                    format!("malformed trace event: {e}"),
                ));
                continue;
            }
        };
        if !KNOWN_CODES.contains(&ev.code.as_str()) {
            report.push(Diagnostic::warn(
                "SL-TRC-002",
                at.as_str(),
                format!("unknown reason code {:?} (kept as-is)", ev.code),
            ));
        }
        if ev.begin_ms < last_begin - 1e-9 {
            report.push(Diagnostic::error(
                "SL-TRC-003",
                at.as_str(),
                format!(
                    "virtual time runs backwards: begin_ms {} after {}",
                    ev.begin_ms, last_begin
                ),
            ));
        }
        last_begin = last_begin.max(ev.begin_ms);
        events.push(ev);
    }
    if !any_line {
        report.push(Diagnostic::error(
            "SL-TRC-001",
            "trace",
            "empty trace file (truncated before any event was written?)"
                .to_string(),
        ));
    }
    (events, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(code: &str, begin: f64, end: f64) -> TraceEvent {
        TraceEvent::new(code, 0, "alpha", Some(1), begin, end, &[("x", 1.5)])
    }

    #[test]
    fn jsonl_round_trips_bit_exact() {
        let events = vec![
            ev(TR_REQ_ARRIVE, 0.0, 0.0),
            ev(TR_REQ_EXEC, 0.25, 17.5),
            TraceEvent::new(TR_CTL_STEAL, 2, "", None, 30.0, 30.0, &[
                ("thief", 2.0),
                ("home", 0.0),
            ]),
        ];
        let text = to_jsonl(&events);
        let (parsed, report) = parse_jsonl(&text);
        assert!(!report.has_errors(), "{}", report.render_text());
        assert_eq!(parsed, events);
        // Re-serialization is byte-identical (the determinism contract).
        assert_eq!(to_jsonl(&parsed), text);
    }

    #[test]
    fn canonical_is_stable_on_ties() {
        let a = TraceEvent::new(TR_REQ_DONE, 0, "a", Some(1), 5.0, 5.0, &[]);
        let b = TraceEvent::new(TR_REQ_DONE, 1, "b", Some(2), 5.0, 5.0, &[]);
        let c = TraceEvent::new(TR_REQ_ARRIVE, 1, "b", Some(2), 1.0, 1.0, &[]);
        let sorted = canonical(vec![a.clone(), b.clone(), c.clone()]);
        assert_eq!(sorted, vec![c, a, b], "ties keep input (shard) order");
    }

    #[test]
    fn lints_flag_truncated_unknown_and_backwards() {
        // Truncated line (cut mid-object).
        let (_, r) = parse_jsonl("{\"code\":\"TR-REQ-DONE\",\"sha");
        assert!(r.render_text().contains("SL-TRC-001"));
        assert!(r.has_errors());
        // Unknown code: warn, event kept.
        let odd = TraceEvent::new("TR-XXX-9", 0, "t", None, 1.0, 1.0, &[]);
        let (evs, r) = parse_jsonl(&to_jsonl(&[odd]));
        assert_eq!(evs.len(), 1);
        assert!(r.render_text().contains("SL-TRC-002"));
        assert!(!r.has_errors(), "unknown codes are warnings, not errors");
        // Non-monotone virtual time.
        let text = to_jsonl(&[ev(TR_REQ_DONE, 9.0, 9.0), ev(TR_REQ_DONE, 3.0, 3.0)]);
        let (_, r) = parse_jsonl(&text);
        assert!(r.render_text().contains("SL-TRC-003"));
        assert!(r.has_errors());
        // Empty file.
        let (_, r) = parse_jsonl("");
        assert!(r.has_errors());
    }

    #[test]
    fn noop_sink_retains_nothing() {
        let mut sink = sink_for(false);
        assert!(!sink.enabled());
        sink.emit(ev(TR_REQ_ARRIVE, 0.0, 0.0));
        assert!(sink.drain().is_empty());
        let mut ring = sink_for(true);
        assert!(ring.enabled());
        ring.emit(ev(TR_REQ_ARRIVE, 0.0, 0.0));
        assert_eq!(ring.drain().len(), 1);
        assert!(ring.drain().is_empty(), "drain empties the buffer");
    }
}
