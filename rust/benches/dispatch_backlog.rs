//! Backlog dispatch bench: bursty overload served by the single-server
//! unbatched baseline vs adaptive batching, multi-server sharding, and
//! the online arms — replan, telemetry-driven stealing, and steal+warm
//! migration (the `exp backlog` study, all arms; `make backlog`). Runs
//! on the real artifact zoo when `artifacts/` is present, else on the
//! synthetic fixture — so it always produces the comparison table,
//! including the estimated-vs-true arrival-rate telemetry table.
//!
//! Run: `cargo bench --bench dispatch_backlog`

use sparseloom::experiments::{endtoend, Ctx};
use sparseloom::fixtures;
use sparseloom::profiler::ProfilerConfig;
use sparseloom::soc::Platform;

fn main() -> anyhow::Result<()> {
    match Ctx::load("artifacts", false) {
        Ok(ctx) => {
            let platform = Platform::desktop();
            let lm = ctx.lm(platform.clone());
            let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;
            let zoo = ctx.zoo_for(&platform);
            println!("{}", endtoend::backlog_comparison(zoo, &lm, &profiles, 6_000.0)?);
        }
        Err(_) => {
            eprintln!("(no artifacts/ — running on the synthetic fixture zoo)\n");
            let (zoo, lm, profiles) = fixtures::trio();
            println!("{}", endtoend::backlog_comparison(&zoo, &lm, &profiles, 6_000.0)?);
        }
    }
    Ok(())
}
