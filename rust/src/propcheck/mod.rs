//! Mini property-testing framework (offline substrate for `proptest`).
//!
//! Provides seeded generators, a `forall` runner with failure reporting,
//! and greedy input shrinking for integer/vector cases. Used by the
//! coordinator-invariant property tests in `rust/tests/proptests.rs`.

use crate::util::Rng;

/// A generator of random values of `T` with an optional shrinker.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Rng) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(gen: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Self { gen: Box::new(gen), shrink: Box::new(|_| Vec::new()) }
    }

    pub fn with_shrink(mut self, shrink: impl Fn(&T) -> Vec<T> + 'static) -> Self {
        self.shrink = Box::new(shrink);
        self
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.gen)(rng)
    }

    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }
}

/// usize in [lo, hi] with halving shrinker toward lo.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo <= hi);
    Gen::new(move |rng| lo + rng.below(hi - lo + 1)).with_shrink(move |&v| {
        let mut out = Vec::new();
        if v > lo {
            out.push(lo);
            let mid = lo + (v - lo) / 2;
            if mid != lo && mid != v {
                out.push(mid);
            }
            if v - 1 != mid && v - 1 >= lo {
                out.push(v - 1);
            }
        }
        out
    })
}

/// f64 in [lo, hi) with shrink toward lo.
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |rng| rng.range_f64(lo, hi)).with_shrink(move |&v| {
        if v > lo + 1e-12 {
            vec![lo, lo + (v - lo) / 2.0]
        } else {
            Vec::new()
        }
    })
}

/// Pick uniformly from a fixed, nonempty set of values; shrinks toward
/// earlier entries (put the "most boring" value first). Used by the
/// sparselint corrupted-corpus property to pick a corruption kind.
pub fn choice<T: Clone + PartialEq + 'static>(items: Vec<T>) -> Gen<T> {
    assert!(!items.is_empty(), "choice() needs at least one item");
    let items = std::rc::Rc::new(items);
    let i2 = std::rc::Rc::clone(&items);
    Gen::new(move |rng| items[rng.below(items.len())].clone()).with_shrink(move |v| {
        match i2.iter().position(|x| x == v) {
            Some(0) | None => Vec::new(),
            Some(i) => vec![i2[0].clone(), i2[i - 1].clone()],
        }
    })
}

/// Vec of fixed length from an element generator (shrinks elements).
pub fn vec_of<T: Clone + 'static>(elem: Gen<T>, len: usize) -> Gen<Vec<T>> {
    let elem = std::rc::Rc::new(elem);
    let e2 = std::rc::Rc::clone(&elem);
    Gen::new(move |rng| (0..len).map(|_| elem.sample(rng)).collect::<Vec<T>>())
        .with_shrink(move |v: &Vec<T>| {
            let mut out = Vec::new();
            for (i, item) in v.iter().enumerate() {
                for s in e2.shrinks(item) {
                    let mut copy = v.clone();
                    copy[i] = s;
                    out.push(copy);
                }
            }
            out
        })
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult<T> {
    Ok { cases: usize },
    Failed { original: T, shrunk: T, message: String },
}

/// Run `prop` on `cases` random inputs; on failure, greedily shrink.
/// `prop` returns Err(message) to signal failure.
pub fn forall<T: Clone + 'static>(
    gen: &Gen<T>,
    cases: usize,
    seed: u64,
    prop: impl Fn(&T) -> Result<(), String>,
) -> PropResult<T> {
    let mut rng = Rng::new(seed);
    for _ in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: keep taking the first failing shrink.
            let mut current = input.clone();
            let mut current_msg = msg;
            'outer: loop {
                for cand in gen.shrinks(&current) {
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        current_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            return PropResult::Failed {
                original: input,
                shrunk: current,
                message: current_msg,
            };
        }
    }
    PropResult::Ok { cases }
}

/// Assert helper: panic with a readable report on failure.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    cases: usize,
    seed: u64,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    match forall(gen, cases, seed, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { original, shrunk, message } => {
            panic!(
                "property {name} failed: {message}\n  original input: {original:?}\n  shrunk input:   {shrunk:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let g = usize_in(0, 100);
        match forall(&g, 200, 1, |&v| {
            if v <= 100 { Ok(()) } else { Err("out of range".into()) }
        }) {
            PropResult::Ok { cases } => assert_eq!(cases, 200),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let g = usize_in(0, 1000);
        match forall(&g, 500, 2, |&v| {
            if v < 37 { Ok(()) } else { Err(format!("{v} ≥ 37")) }
        }) {
            PropResult::Failed { shrunk, .. } => {
                assert_eq!(shrunk, 37, "greedy shrink reaches the boundary");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn choice_samples_and_shrinks_toward_front() {
        let g = choice(vec!["a", "b", "c"]);
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            assert!(["a", "b", "c"].contains(&g.sample(&mut rng)));
        }
        assert!(g.shrinks(&"a").is_empty(), "front item is fully shrunk");
        assert!(g.shrinks(&"c").contains(&"a"));
        assert!(g.shrinks(&"c").contains(&"b"));
    }

    #[test]
    fn vec_generator_and_shrinker() {
        let g = vec_of(usize_in(0, 9), 4);
        let mut rng = Rng::new(3);
        let v = g.sample(&mut rng);
        assert_eq!(v.len(), 4);
        assert!(v.iter().all(|&x| x <= 9));
        let big = vec![9usize, 9, 9, 9];
        assert!(!g.shrinks(&big).is_empty());
    }

    #[test]
    #[should_panic(expected = "property demo failed")]
    fn check_panics_with_report() {
        let g = usize_in(0, 10);
        check("demo", &g, 100, 4, |&v| {
            if v < 5 { Ok(()) } else { Err("too big".into()) }
        });
    }
}
