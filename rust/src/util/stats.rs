//! Small statistics helpers shared by the profiler, metrics, and benches.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for fewer than two samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, q in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Mean absolute error between predictions and ground truth.
pub fn mae(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(truth)
        .map(|(p, t)| (p - t).abs())
        .sum::<f64>()
        / pred.len() as f64
}

/// Mean absolute percentage error (%). Skips zero-truth entries.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut acc = 0.0;
    let mut n = 0usize;
    for (p, t) in pred.iter().zip(truth) {
        if t.abs() > 1e-12 {
            acc += ((p - t) / t).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * acc / n as f64
    }
}

/// Coefficient of determination R².
pub fn r2(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let m = mean(truth);
    let ss_res: f64 = pred.iter().zip(truth).map(|(p, t)| (t - p).powi(2)).sum();
    let ss_tot: f64 = truth.iter().map(|t| (t - m).powi(2)).sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            0.0
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Top-K recall: fraction of the predicted top-K whose *true* value
/// ties or beats the K-th best true value (the paper's Fig. 7a metric).
/// Tie-tolerant: measured accuracies are quantized (multiples of
/// 1/n_eval), so many variants share the K-th value and any of them is
/// a correct retrieval.
pub fn top_k_recall(pred: &[f64], truth: &[f64], k: usize) -> f64 {
    top_k_recall_eps(pred, truth, k, 1e-12)
}

/// `top_k_recall` with an explicit tie margin `eps`: a retrieved item
/// counts if its true value is within `eps` of the K-th best. Use the
/// measurement quantum (1/n_eval for accuracies measured on n_eval
/// samples) — ranking below measurement resolution is noise.
pub fn top_k_recall_eps(pred: &[f64], truth: &[f64], k: usize, eps: f64) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if k == 0 || pred.is_empty() {
        return 1.0;
    }
    let k = k.min(pred.len());
    let top = |xs: &[f64]| -> Vec<usize> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
        idx.truncate(k);
        idx
    };
    let pt = top(pred);
    let kth_true = {
        let mut t = truth.to_vec();
        t.sort_by(|a, b| b.partial_cmp(a).unwrap());
        t[k - 1]
    };
    let hits = pt.iter().filter(|&&i| truth[i] >= kth_true - eps).count();
    hits as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn mae_mape() {
        assert_eq!(mae(&[1.0, 2.0], &[2.0, 4.0]), 1.5);
        assert!((mape(&[1.0, 2.0], &[2.0, 4.0]) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn r2_perfect_and_mean() {
        let t = [1.0, 2.0, 3.0];
        assert!((r2(&t, &t) - 1.0).abs() < 1e-12);
        assert!(r2(&[2.0, 2.0, 2.0], &t).abs() < 1e-12);
    }

    #[test]
    fn top_k_recall_basic() {
        let truth = [0.9, 0.1, 0.8, 0.2];
        let perfect = truth;
        assert_eq!(top_k_recall(&perfect, &truth, 2), 1.0);
        let inverted = [0.1, 0.9, 0.2, 0.8];
        assert_eq!(top_k_recall(&inverted, &truth, 2), 0.0);
    }

    #[test]
    fn top_k_recall_partial() {
        let truth = [1.0, 0.9, 0.1, 0.0];
        let pred = [1.0, 0.0, 0.9, 0.1];
        assert_eq!(top_k_recall(&pred, &truth, 2), 0.5);
    }
}
