"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel path in :mod:`sparse_matmul` has an oracle here. pytest
(``python/tests/test_kernel.py``) asserts ``allclose`` between kernel and
oracle across a hypothesis-driven sweep of shapes/dtypes/sparsities — this
is the core L1 correctness signal.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Dense matmul + bias: ``x @ w + b``; accumulate in f32."""
    acc = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    return acc + b.astype(jnp.float32)


def masked_matmul_ref(
    x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray, b: jnp.ndarray
) -> jnp.ndarray:
    """Unstructured-sparse matmul: weights zero-masked elementwise.

    ``mask`` is {0,1} with the same shape as ``w``; this is the
    zero-masking form of unstructured pruning the paper's Intel zoos use.
    """
    wm = (w * mask).astype(jnp.float32)
    return jnp.matmul(x.astype(jnp.float32), wm) + b.astype(jnp.float32)


def block_sparse_matmul_ref(
    x: jnp.ndarray, w: jnp.ndarray, row_keep: jnp.ndarray, b: jnp.ndarray
) -> jnp.ndarray:
    """Structured (channel) pruning: whole input-rows of ``w`` dropped.

    ``row_keep`` is a {0,1} vector of length ``K = w.shape[0]``; a zero
    entry removes input channel k (row k of w) from the contraction. The
    interface shapes are unchanged — channels are masked, not reshaped —
    which is what keeps subgraph interfaces layer-aligned for stitching.
    """
    wk = (w * row_keep[:, None]).astype(jnp.float32)
    return jnp.matmul(x.astype(jnp.float32), wk) + b.astype(jnp.float32)


def quant_matmul_ref(
    x: jnp.ndarray, wq: jnp.ndarray, scale: jnp.ndarray, b: jnp.ndarray
) -> jnp.ndarray:
    """Full-INT8 matmul: int8 weights *and* dynamically-quantized
    activations (dequant after the integer contraction).

    ``wq`` is int8, ``scale`` an (N,) f32 weight scale. Activations are
    quantized per-row symmetric to int8 at runtime (dynamic quantization,
    the ONNX-Runtime/OpenVINO INT8 execution model) — this is where real
    INT8 pipelines lose accuracy, so the zoo's quantized variant carries
    an honest cost.
    """
    xf = x.astype(jnp.float32)
    sx = jnp.max(jnp.abs(xf), axis=1, keepdims=True) / 127.0
    sx = jnp.where(sx > 0, sx, 1.0)
    xq = jnp.clip(jnp.round(xf / sx), -127, 127)
    acc = jnp.matmul(xq, wq.astype(jnp.float32))
    w_scaled = acc * sx * scale.astype(jnp.float32)[None, :]
    return w_scaled + b.astype(jnp.float32)


def fake_quant_weights_ref(w: jnp.ndarray, bits: int = 8):
    """Symmetric *per-tensor* fake quantization of a weight matrix.

    Returns ``(wq_int, scale)`` with ``wq_int`` in [-(2^{b-1}-1), 2^{b-1}-1]
    and an (N,) f32 ``scale`` (one value broadcast across columns — the
    kernel interface stays per-column) so that ``wq_int * scale ≈ w``.
    Per-tensor scaling is what cheap post-training INT8 pipelines use and
    it loses measurable accuracy, which keeps the zoo's accuracy–latency
    trade-off honest (per-channel INT8 on these tiny models is lossless,
    collapsing the Pareto frontier to a single dominating variant).
    """
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(w))
    scale_val = jnp.where(absmax > 0, absmax / qmax, 1.0).astype(jnp.float32)
    scale = jnp.full((w.shape[1],), scale_val, jnp.float32)
    wq = jnp.clip(jnp.round(w / scale_val), -qmax, qmax)
    return wq.astype(jnp.int8), scale
