# Build-path entry points. The only Python step is the artifact export;
# everything else is `cargo` (see scripts/ci.sh for the tier-1 gate).

.PHONY: artifacts ci bench backlog

# Export the L1/L2 model-zoo artifacts the Rust serving system consumes
# (manifest, HLO text, weight blobs, probe/eval tensors, oracles).
artifacts:
	cd python/compile && python3 aot.py --out ../../artifacts

ci:
	scripts/ci.sh

# The `exp backlog` study with all arms — static / replan / steal /
# steal+warm — plus the estimated-vs-true arrival-rate telemetry table.
# Artifact-free: falls back to the synthetic fixture zoo.
backlog:
	cargo bench --bench dispatch_backlog

# All benchmarks: the backlog study plus the Algorithm 1 microbench.
bench: backlog
	cargo bench --bench planner_cost
