//! Thread-pool executor (offline substrate for `tokio`).
//!
//! The coordinator's per-processor engines each own a worker thread fed
//! by an mpsc channel; this module provides the shared pieces: a
//! fixed-size `ThreadPool` with `scope`-less job submission and a
//! `fan_out` helper used by the profiler to parallelize independent
//! measurements. Everything is std-only.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed-size pool of worker threads.
pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::Builder::new()
                    .name(format!("sparseloom-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                job();
                                pending.fetch_sub(1, Ordering::AcqRel);
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx, workers, pending }
    }

    /// Submit a job; returns immediately.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.pending.fetch_add(1, Ordering::AcqRel);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Acquire)
    }

    /// Busy-wait (with yields) until all submitted jobs completed.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for `i in 0..n` on a temporary pool and collect results in
/// index order. Results must be `Send`.
pub fn fan_out<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    if n == 0 {
        return Vec::new();
    }
    let f = Arc::new(f);
    let pool = ThreadPool::new(threads.min(n));
    let (tx, rx): (Sender<(usize, T)>, Receiver<(usize, T)>) = channel();
    for i in 0..n {
        let f = Arc::clone(&f);
        let tx = tx.clone();
        pool.submit(move || {
            let out = f(i);
            let _ = tx.send((i, out));
        });
    }
    drop(tx);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in rx.iter() {
        slots[i] = Some(v);
    }
    slots.into_iter().map(|s| s.unwrap()).collect()
}

/// A single-consumer work queue feeding one dedicated worker thread —
/// the shape of a per-processor inference engine.
pub struct Worker<T: Send + 'static> {
    tx: Sender<Option<T>>,
    handle: Option<JoinHandle<()>>,
}

impl<T: Send + 'static> Worker<T> {
    /// Spawn a worker running `handler` for every item until shutdown.
    pub fn spawn<F>(name: &str, mut handler: F) -> Self
    where
        F: FnMut(T) + Send + 'static,
    {
        let (tx, rx) = channel::<Option<T>>();
        let handle = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || {
                while let Ok(Some(item)) = rx.recv() {
                    handler(item);
                }
            })
            .expect("spawn worker");
        Self { tx, handle: Some(handle) }
    }

    pub fn send(&self, item: T) {
        self.tx.send(Some(item)).expect("worker alive");
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(None);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<T: Send + 'static> Drop for Worker<T> {
    fn drop(&mut self) {
        let _ = self.tx.send(None);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn fan_out_preserves_order() {
        let out = fan_out(32, 4, |i| i * i);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn fan_out_empty() {
        let out: Vec<usize> = fan_out(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn worker_processes_in_order() {
        let (tx, rx) = channel();
        let w = Worker::spawn("t", move |x: usize| {
            tx.send(x).unwrap();
        });
        for i in 0..10 {
            w.send(i);
        }
        w.shutdown();
        let got: Vec<usize> = rx.iter().collect();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }
}
