"""Compression invariants: the zoo behaves like Table 5 says it should."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import compress, model as M
from compile.kernels import ref


def test_zoo_sizes_match_table5():
    assert len(compress.intel_zoo()) == 10
    assert len(compress.jetson_zoo()) == 10
    intel = {v.vtype for v in compress.intel_zoo()}
    assert intel == {"dense", "int8", "unstructured", "structured"}
    jetson = {v.vtype for v in compress.jetson_zoo()}
    assert jetson == {"dense", "fp16", "int8", "structured"}


def test_zoo_names_unique():
    for zoo in (compress.intel_zoo(), compress.jetson_zoo()):
        names = [v.name for v in zoo]
        assert len(names) == len(set(names))


@settings(max_examples=20, deadline=None)
@given(sparsity=st.sampled_from([0.2, 0.4, 0.5, 0.65, 0.8, 0.9]),
       seed=st.integers(0, 2**31 - 1))
def test_unstructured_mask_fraction(sparsity, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    b = jnp.zeros(32, jnp.float32)
    _, mask, _ = compress._prune_unstructured([w, b], sparsity)
    frac = 1.0 - float(np.mean(np.asarray(mask)))
    assert abs(frac - sparsity) < 1.0 / mask.size + 1e-6


@settings(max_examples=20, deadline=None)
@given(sparsity=st.sampled_from([0.2, 0.4, 0.5, 0.55]),
       seed=st.integers(0, 2**31 - 1))
def test_structured_keep_fraction(sparsity, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    b = jnp.zeros(32, jnp.float32)
    _, keep, _ = compress._prune_structured([w, b], sparsity)
    dropped = int(64 - np.sum(np.asarray(keep)))
    assert dropped == int(round(sparsity * 64))
    assert np.sum(np.asarray(keep)) >= 1  # never prunes everything


def test_unstructured_prunes_smallest_magnitudes():
    w = jnp.asarray(np.arange(1, 33, dtype=np.float32).reshape(8, 4))
    b = jnp.zeros(4, jnp.float32)
    _, mask, _ = compress._prune_unstructured([w, b], 0.25)
    flat = np.asarray(mask).ravel()
    assert (flat[:8] == 0).all() and (flat[8:] == 1).all()


def test_structured_prunes_lowest_norm_rows():
    w = np.ones((8, 4), np.float32) * np.arange(1, 9)[:, None]
    _, keep, _ = compress._prune_structured(
        [jnp.asarray(w), jnp.zeros(4, jnp.float32)], 0.5
    )
    assert np.array_equal(np.asarray(keep), [0, 0, 0, 0, 1, 1, 1, 1])


def test_int8_quant_tensors():
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    b = jnp.zeros(16, jnp.float32)
    wq, scale, _ = compress._quant_int8([w, b])
    assert wq.dtype == jnp.int8
    assert scale.shape == (16,)
    recon = np.asarray(wq, np.float32) * np.asarray(scale)[None, :]
    assert np.max(np.abs(recon - np.asarray(w))) <= 0.5 * np.max(
        np.asarray(scale)
    ) + 1e-6


def test_fp16_roundtrip_close():
    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    b = jnp.zeros(16, jnp.float32)
    w16, _ = compress._cast_fp16([w, b])
    assert w16.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(w16), np.asarray(w), rtol=2e-3)


def test_layernorm_params_not_compressed():
    params = M.init_params("sentiment")
    vs = compress.intel_zoo()[2]  # unstr90
    out = compress.compress_model(params, vs)
    # ln layers keep exactly 2 tensors; GEMM layers gained a mask.
    assert len(out[0]["enc1"]["ln1"]) == 2
    assert len(out[0]["enc1"]["wq"]) == 3


def test_dense_spec_is_identity():
    params = M.init_params("asr")
    out = compress.compress_model(params, compress.intel_zoo()[0])
    a = M.flatten_params(params[0])
    b = M.flatten_params(out[0])
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("task", M.TASK_NAMES)
def test_compression_preserves_interfaces(task):
    """All zoo variants keep the same flat-param *shapes per path*."""
    params = M.init_params(task)
    shapes_by_path = {}
    for vs in compress.intel_zoo():
        out = compress.compress_model(params, vs)
        shapes = tuple(
            tuple(t.shape) for j in range(M.SUBGRAPHS)
            for t in M.flatten_params(out[j])
        )
        prev = shapes_by_path.setdefault(vs.kernel_path, shapes)
        assert prev == shapes
