//! SparseLoom CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   serve       run a serving scenario (closed-loop, Poisson, bursty, file)
//!   bench       fleet-scale throughput sweep (writes BENCH_fleet.json)
//!   lint        static-analyze Scenario JSON files (sparselint)
//!   exp         regenerate a paper table/figure (or `all`)
//!   profile     build + report the performance profile (estimators)
//!   calibrate   measure PJRT base latencies and write the cache
//!   probe       verify rust-side numerics against python expectations
//!   zoo         print the loaded sparse model zoo

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use sparseloom::analysis;
use sparseloom::baselines::Policy;
use sparseloom::cli::{App, Command};
use sparseloom::json::Json;
use sparseloom::coordinator::ServeOpts;
use sparseloom::experiments::{self, Ctx};
use sparseloom::fixtures;
use sparseloom::metrics::{RunReport, ShardedReport};
use sparseloom::profiler::ProfilerConfig;
use sparseloom::runtime::Runtime;
use sparseloom::scenario::{
    Admission, Dispatch, Scenario, ServeConfig, Server, ShardedServer, Sharding, Workload,
};
use sparseloom::soc::Platform;
use sparseloom::trace;
use sparseloom::workload::{slo_grid, TaskRanges};
use sparseloom::zoo::Zoo;

fn app() -> App {
    App {
        name: "sparseloom",
        about: "multi-DNN inference of sparse models on (simulated) edge SoCs",
        commands: vec![
            Command::new("serve", "run a serving scenario on one SLO config")
                .opt("artifacts", "artifact directory", Some("artifacts"))
                .opt("platform", "desktop|laptop|orin", Some("desktop"))
                .opt("policy", "SparseLoom or a baseline name", Some("SparseLoom"))
                .opt("scenario", "closed|poisson|bursty (or use --scenario-file)", Some("closed"))
                .opt("scenario-file", "load a Scenario JSON file (overrides workload flags)", None)
                .opt("save-scenario", "write the constructed scenario as JSON", None)
                .opt("queries", "closed loop: queries per task", Some("100"))
                .opt("stagger-ms", "closed loop: per-slot start stagger", Some("0"))
                .opt("rate-qps", "open loop: per-task arrival rate", Some("20"))
                .opt("horizon-ms", "open loop: stream horizon", Some("5000"))
                .opt("burst-qps", "bursty: second-half-of-period rate", Some("80"))
                .opt("period-ms", "bursty: rate square-wave period", Some("1000"))
                .opt("admission", "always | queue:<N> | deadline:<slack> | fair[:<slack>] | predictive[:<headroom>[:<horizon-ms>]]", Some("always"))
                .opt("shards", "partition tasks across N servers (task-name hash)", Some("1"))
                .opt("max-batch", "coalesce up to K same-task queries under backlog", Some("1"))
                .opt("min-queue", "waiting queries before batching kicks in", Some("2"))
                .opt("batch-hint", "plan batch-aware at this expected batch size (default: max-batch when --replan)", None)
                .switch("replan", "alias for ServeConfig::replan (deprecated spelling, kept for compatibility): online re-planning — migrate the hottest task off a saturated shard")
                .switch("steal", "alias for ServeConfig::steal (deprecated spelling): telemetry-driven work stealing — an underloaded shard serves a saturated shard's waiting batches")
                .switch("warm-migrate", "alias for ServeConfig::warm_migrate (deprecated spelling): carry a migrant's pool contents to the target shard (cross-shard load instead of cold compile); implies --replan unless --steal is set")
                .switch("predictive", "alias for ServeConfig::predictive (deprecated spelling): trigger replan/steal on forecast (not observed) shard backlog and feed projected arrival rates to the planner; implies --replan unless --steal is set")
                .switch("synthesize", "online stitched-variant synthesis: under backlog or pool pressure the planner searches the stitch space for a cheaper composition and switches to it (TR-CTL-SYNTH audit events; implies batch-aware planning)")
                .opt("seed", "arrival-stream seed", Some("0"))
                .opt("slo", "grid index 0..24 of the SLO config", Some("12"))
                .opt("budget", "memory budget fraction of full preload", Some("1.0"))
                .switch("real", "execute real PJRT chains during serving")
                .switch("synthetic", "flops-derived base latencies (no PJRT)")
                .switch("fixture", "serve the synthetic in-memory fixture zoo (hermetic; needs no artifacts/)")
                .switch("verify", "replay the finished run through the sparselint invariant verifier (SL-INV-*); violations fail the command")
                .opt("trace", "write the canonical run trace (request spans + control-plane audit events) to this path", None)
                .opt("trace-format", "trace file format: jsonl (one event per line, replayable by `explain`) | chrome (trace-event JSON for Perfetto / chrome://tracing)", Some("jsonl"))
                .switch("json", "emit the full run report as JSON on stdout (suppresses the text report)")
                .switch("sequential", "drive sharded runs inline on one thread (threaded is the default; report and trace are bit-identical either way)"),
            Command::new("bench", "fleet-scale throughput sweep on the hermetic fleet fixture")
                .opt("tasks", "fleet fixture size (tasks)", Some("16"))
                .opt("rate-qps", "per-task Poisson arrival rate", Some("40"))
                .opt("horizon-ms", "stream horizon per arm", Some("3000"))
                .opt("shards", "comma-separated shard counts to sweep", Some("1,2,4"))
                .opt("iters", "timed repetitions per arm (best is reported)", Some("3"))
                .opt("out", "output JSON path", Some("BENCH_fleet.json"))
                .opt("gate", "baseline JSON: fail the run on speedup regression", None)
                .opt("tolerance", "allowed fractional speedup regression vs the gate baseline", Some("0.2")),
            Command::new("lint", "static-analyze Scenario JSON files (sparselint)")
                .opt("artifacts", "artifact directory for the zoo feasibility pass", Some("artifacts"))
                .opt("platform", "desktop|laptop|orin", Some("desktop"))
                .switch("fixture", "run the feasibility pass against the in-memory fixture zoo (hermetic; needs no artifacts/)")
                .switch("synthetic", "flops-derived base latencies (no PJRT)")
                .switch("json", "emit diagnostics as JSON instead of text"),
            Command::new("explain", "attribute a trace's SLO violations to dominant causes"),
            Command::new("exp", "regenerate a paper table/figure")
                .opt("artifacts", "artifact directory", Some("artifacts"))
                .opt("horizon-ms", "backlog study: bursty stream horizon", Some("6000"))
                .switch("synthetic", "flops-derived base latencies (no PJRT)")
                .switch("fixture", "run `exp backlog` on the in-memory fixture zoo (hermetic)")
                .switch("json", "backlog study: emit per-arm reports as JSON instead of the text tables"),
            Command::new("profile", "build the estimator profile and report quality")
                .opt("artifacts", "artifact directory", Some("artifacts"))
                .opt("platform", "desktop|laptop|orin", Some("desktop"))
                .opt("train-samples", "stitched variants used to train the GBDT", Some("80"))
                .switch("synthetic", "flops-derived base latencies (no PJRT)"),
            Command::new("calibrate", "measure PJRT base latencies, write cache")
                .opt("artifacts", "artifact directory", Some("artifacts"))
                .opt("iters", "timing iterations per executable", Some("30")),
            // Tolerance note: dynamic-INT8 activation rounding amplifies
            // cross-XLA-version ULP differences by one quantization step
            // (~0.1 % of logit scale), hence 0.05 rather than float-noise.
            Command::new("probe", "verify PJRT numerics vs python expectations")
                .opt("artifacts", "artifact directory", Some("artifacts"))
                .opt("tolerance", "max |Δlogit|", Some("0.05")),
            Command::new("zoo", "print the loaded sparse model zoo")
                .opt("artifacts", "artifact directory", Some("artifacts")),
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    match app.dispatch(&argv) {
        Err(e) => {
            eprintln!("{}", e.0);
            std::process::exit(2);
        }
        Ok((cmd, args)) => {
            let r = match cmd.name {
                "serve" => cmd_serve(&args),
                "bench" => cmd_bench(&args),
                "lint" => cmd_lint(&args),
                "explain" => cmd_explain(&args),
                "exp" => cmd_exp(&args),
                "profile" => cmd_profile(&args),
                "calibrate" => cmd_calibrate(&args),
                "probe" => cmd_probe(&args),
                "zoo" => cmd_zoo(&args),
                _ => unreachable!(),
            };
            if let Err(e) = r {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

/// Parse `always` / `queue:<N>` / `deadline:<slack>` / `fair[:<slack>]`
/// admission specs.
fn parse_admission(spec: &str) -> Result<Admission> {
    if spec.eq_ignore_ascii_case("always") || spec.eq_ignore_ascii_case("none") {
        return Ok(Admission::Always);
    }
    if let Some(n) = spec.strip_prefix("queue:") {
        let max_queued = n
            .parse()
            .map_err(|_| anyhow::anyhow!("queue:<N> expects an integer, got {n:?}"))?;
        return Ok(Admission::QueueCap { max_queued });
    }
    if let Some(s) = spec.strip_prefix("deadline:") {
        let slack = s
            .parse()
            .map_err(|_| anyhow::anyhow!("deadline:<slack> expects a number, got {s:?}"))?;
        return Ok(Admission::Deadline { slack });
    }
    if spec.eq_ignore_ascii_case("fair") {
        return Ok(Admission::Fair { slack: 2.0, weights: BTreeMap::new() });
    }
    if let Some(s) = spec.strip_prefix("fair:") {
        let slack = s
            .parse()
            .map_err(|_| anyhow::anyhow!("fair:<slack> expects a number, got {s:?}"))?;
        return Ok(Admission::Fair { slack, weights: BTreeMap::new() });
    }
    if spec.eq_ignore_ascii_case("predictive") {
        return Ok(Admission::Predictive { horizon_ms: 250.0, headroom: 1.0 });
    }
    if let Some(rest) = spec.strip_prefix("predictive:") {
        let (head, horizon) = match rest.split_once(':') {
            Some((h, hz)) => (h, Some(hz)),
            None => (rest, None),
        };
        let headroom: f64 = head.parse().map_err(|_| {
            anyhow::anyhow!("predictive:<headroom> expects a number, got {head:?}")
        })?;
        let horizon_ms: f64 = match horizon {
            None => 250.0,
            Some(hz) => hz.parse().map_err(|_| {
                anyhow::anyhow!(
                    "predictive:<headroom>:<horizon-ms> expects a number, got {hz:?}"
                )
            })?,
        };
        return Ok(Admission::Predictive { horizon_ms, headroom });
    }
    bail!(
        "unknown admission spec {spec:?} \
         (want always | queue:<N> | deadline:<slack> | fair[:<slack>] \
          | predictive[:<headroom>[:<horizon-ms>]])"
    )
}

fn cmd_serve(args: &sparseloom::cli::Args) -> Result<()> {
    let platform = Platform::by_name(&args.get_or("platform", "desktop"))?;
    let policy = Policy::parse(&args.get_or("policy", "SparseLoom"))
        .ok_or_else(|| anyhow::anyhow!("unknown policy"))?;
    // `--fixture` serves the synthetic in-memory zoo — fully hermetic
    // (the CI smoke stage relies on this); otherwise artifacts load.
    let ctx;
    let fixture_zoo;
    let (zoo, lm, profiles): (&Zoo, _, _) = if args.switch("fixture") {
        let (z, lm, profiles) = fixtures::quartet();
        fixture_zoo = z;
        (&fixture_zoo, lm, profiles)
    } else {
        ctx = Ctx::load(&args.get_or("artifacts", "artifacts"), args.switch("synthetic"))?;
        let lm = ctx.lm(platform.clone());
        let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;
        (ctx.zoo_for(&platform), lm, profiles)
    };

    let tasks: Vec<String> = profiles.keys().cloned().collect();

    // --- construct (or load) the typed scenario -------------------------
    // A scenario file carries its own SLO schedule; the grid-derived
    // SLO config only applies when the scenario is built from flags.
    let mut slo_note = String::new();
    let scenario = if let Some(path) = args.get("scenario-file") {
        Scenario::load(path)?
    } else {
        let slo_idx = args.get_usize("slo")?.unwrap_or(12);
        let mut slos = BTreeMap::new();
        let mut universe = Vec::new();
        for (name, tz) in &zoo.tasks {
            let grid = slo_grid(&TaskRanges::measure(tz, &lm));
            universe.extend(grid.iter().copied());
            slos.insert(name.clone(), grid[slo_idx.min(grid.len() - 1)]);
        }
        slo_note = format!(" | SLO grid idx {slo_idx}");
        // The legacy workload / planner flags are thin aliases over the
        // ServeConfig builder: CLI, Scenario JSON and tests all produce
        // the run blocks through the same API (and the same coupling
        // rules — e.g. --warm-migrate pulling in --replan).
        let kind = args.get_or("scenario", "closed");
        let workload = match kind.as_str() {
            "closed" => Workload::Closed {
                queries: args.get_usize("queries")?.unwrap_or(100),
                stagger_ms: args.get_f64("stagger-ms")?.unwrap_or(0.0),
            },
            "poisson" => Workload::Poisson {
                rate_qps: args.get_f64("rate-qps")?.unwrap_or(20.0),
                horizon_ms: args.get_f64("horizon-ms")?.unwrap_or(5_000.0),
            },
            "bursty" => Workload::Bursty {
                base_qps: args.get_f64("rate-qps")?.unwrap_or(20.0),
                burst_qps: args.get_f64("burst-qps")?.unwrap_or(80.0),
                period_ms: args.get_f64("period-ms")?.unwrap_or(1_000.0),
                horizon_ms: args.get_f64("horizon-ms")?.unwrap_or(5_000.0),
            },
            other => bail!("unknown scenario {other:?} (want closed|poisson|bursty)"),
        };
        let mut cfg = ServeConfig::new()
            .workload(workload)
            .admission(parse_admission(&args.get_or("admission", "always"))?)
            .batching(
                args.get_usize("max-batch")?.unwrap_or(1),
                args.get_usize("min-queue")?.unwrap_or(2),
            )
            .shards(args.get_usize("shards")?.unwrap_or(1))
            .seed(args.get_usize("seed")?.unwrap_or(0) as u64);
        if args.switch("replan") {
            cfg = cfg.replan();
        }
        if args.switch("steal") {
            cfg = cfg.steal();
        }
        if args.switch("warm-migrate") {
            cfg = cfg.warm_migrate();
        }
        if args.switch("predictive") {
            cfg = cfg.predictive();
        }
        if args.switch("synthesize") {
            cfg = cfg.synthesize();
        }
        cfg.build(&tasks, slos).with_universe(universe)
    };
    if let Some(path) = args.get("save-scenario") {
        scenario.save(path)?;
        println!("wrote scenario to {path}");
    }

    // `--json` keeps stdout machine-readable: the report document is
    // the only thing printed there; advisory text moves to stderr.
    let json_out = args.switch("json");
    // The header reads from the *scenario* (not the raw flags), so a
    // saved scenario file and the printed report always agree.
    if !json_out {
        println!(
            "scenario: {} | policy: {} | platform: {}{} | admission: {} | shards: {} | max-batch: {} | replan: {} | steal: {} | warm: {} | predictive: {} | synth: {}",
            scenario.name,
            policy.name(),
            lm.platform.name,
            slo_note,
            scenario.admission.label(),
            scenario.sharding.shards,
            scenario.dispatch.max_batch,
            scenario.planner.replan,
            scenario.planner.steal,
            scenario.planner.warm_migrate,
            scenario.planner.predictive,
            scenario.planner.synthesize,
        );
    }

    // --- build the server(s) and run ------------------------------------
    // Batch-aware planning: explicit --batch-hint wins; a batch-aware
    // planner config defaults to the dispatch operating point.
    let batch_hint = match args.get_f64("batch-hint")? {
        Some(h) => h.max(1.0),
        None if scenario.planner.batch_aware => {
            scenario.dispatch.max_batch.max(1) as f64
        }
        None => 1.0,
    };
    let trace_path = args.get("trace").map(str::to_string);
    let trace_format = args.get_or("trace-format", "jsonl");
    let opts = ServeOpts {
        memory_budget_frac: args.get_f64("budget")?.unwrap_or(1.0),
        policy,
        batch_hint,
        // Retaining every request event costs O(requests) memory; only
        // the --verify replay needs the full log — everything the
        // report prints below comes from the streaming aggregates.
        record_events: args.switch("verify"),
        parallel: !args.switch("sequential"),
        trace: trace_path.is_some(),
        ..Default::default()
    };
    // SL-XLY-010: tracing without event retention still produces the
    // full trace, but `--verify`'s trace-consistency pass (SL-INV-006+)
    // cannot cross-check it — surface that before the run.
    let mode = analysis::trace_mode_gate(opts.trace, opts.record_events);
    if !mode.is_empty() {
        eprintln!("{}", mode.render_text());
    }
    if scenario.sharding.shards > 1 {
        if args.switch("real") {
            bail!("--real is single-server only (drop --shards or run with 1 shard)");
        }
        let sharded =
            ShardedServer::build(zoo, &lm, &profiles, opts, scenario.sharding.clone())?;
        let report = sharded.run(&scenario)?;
        if !json_out {
            for (i, shard) in report.per_shard.iter().enumerate() {
                let util = report
                    .budget_utilization
                    .get(i)
                    .map(|u| format!(" | pool {:.0}%", 100.0 * u))
                    .unwrap_or_default();
                println!(
                    "  shard {i}: {} done | {} dropped | {} batches | makespan {:.1} ms{util}",
                    shard.total_queries,
                    shard.total_dropped,
                    shard.total_batches,
                    shard.makespan_ms,
                );
            }
            if report.replans > 0
                || report.migrations > 0
                || report.steals > 0
                || report.synths > 0
            {
                println!(
                    "  online: {} saturation event(s), {} migration(s), {} stolen batch(es), \
                     {} synthesis switch(es), {} cold compile(s), {} warm load(s)",
                    report.replans,
                    report.migrations,
                    report.steals,
                    report.synths,
                    report.aggregate.cold_compiles,
                    report.aggregate.warm_loads,
                );
            }
            if !report.arrival_est_qps.is_empty() {
                let est: Vec<String> = report
                    .arrival_est_qps
                    .iter()
                    .map(|(task, qps)| format!("{task} {qps:.1}"))
                    .collect();
                println!("  telemetry est rate (qps): {}", est.join(" | "));
            }
            if report.aggregate.downtime_ms > 0.0
                || report.aggregate.throttled_ms > 0.0
                || report.link_cost_ms > 0.0
            {
                println!(
                    "  faults: {:.1} ms down | {:.1} ms throttled | {:.1} ms link cost | \
                     {} recovery(ies)",
                    report.aggregate.downtime_ms,
                    report.aggregate.throttled_ms,
                    report.link_cost_ms,
                    report.aggregate.recoveries.len(),
                );
            }
            print_outcomes(&report.aggregate);
            print_forecast(&report.aggregate);
            print_summary(&report.aggregate);
        }
        if args.switch("verify") {
            let inv = analysis::invariants::verify_sharded(&report);
            if !inv.is_empty() {
                status(json_out, &inv.render_text());
            }
            inv.fail_on_errors("run invariants")?;
            status(
                json_out,
                &format!(
                    "invariants OK: {} request event(s) across {} shard(s) verified",
                    report.aggregate.requests.len(),
                    report.per_shard.len(),
                ),
            );
        }
        if let Some(path) = &trace_path {
            let events = report.canonical_trace();
            write_trace(path, &events, &trace_format)?;
            status(
                json_out,
                &format!("wrote {} trace event(s) to {path}", events.len()),
            );
        }
        if json_out {
            println!("{}", report.to_json().to_string_pretty());
        }
        check_fault_expects(&scenario, &report, json_out)?;
    } else {
        let rt;
        let mut builder = Server::builder(zoo, &lm, &profiles).opts(opts);
        if args.switch("real") {
            rt = Runtime::new()?;
            builder = builder.runtime(&rt);
        }
        let server = builder.build();
        let report = server.run(&scenario)?;
        if !json_out {
            print_outcomes(&report);
            print_forecast(&report);
            print_summary(&report);
        }
        if args.switch("verify") {
            let inv = analysis::invariants::verify_report(&report);
            if !inv.is_empty() {
                status(json_out, &inv.render_text());
            }
            inv.fail_on_errors("run invariants")?;
            status(
                json_out,
                &format!(
                    "invariants OK: {} request event(s) across 1 shard(s) verified",
                    report.requests.len(),
                ),
            );
        }
        if let Some(path) = &trace_path {
            // A single session canonicalizes at finish; multi-phase
            // merges concatenate per-phase traces, so re-sort here.
            let events = trace::canonical(report.trace.clone());
            write_trace(path, &events, &trace_format)?;
            status(
                json_out,
                &format!("wrote {} trace event(s) to {path}", events.len()),
            );
        }
        if json_out {
            println!("{}", report.to_json().to_string_pretty());
        }
        // The expect vocabulary is defined over sharded reports; a
        // single-server run is the one-shard special case.
        let wrapped = ShardedReport {
            per_shard: vec![report.clone()],
            aggregate: report,
            ..Default::default()
        };
        check_fault_expects(&scenario, &wrapped, json_out)?;
    }
    Ok(())
}

/// Route advisory lines to stderr when stdout is reserved for a JSON
/// document (`--json`), to stdout otherwise.
fn status(json_out: bool, line: &str) {
    if json_out {
        eprintln!("{line}");
    } else {
        println!("{line}");
    }
}

/// Serialize a canonical trace to `path` in the requested format.
fn write_trace(path: &str, events: &[trace::TraceEvent], format: &str) -> Result<()> {
    let text = match format {
        "jsonl" => trace::to_jsonl(events),
        "chrome" => trace::export::to_chrome(events).to_string_pretty(),
        other => bail!("unknown trace format {other:?} (want jsonl | chrome)"),
    };
    std::fs::write(path, text)?;
    Ok(())
}

/// Check a scenario's declarative `expect` clauses against the finished
/// run; failed clauses are `SL-EXP-*` errors and fail the command.
fn check_fault_expects(scenario: &Scenario, report: &ShardedReport, quiet: bool) -> Result<()> {
    if scenario.faults.expects.is_empty() {
        return Ok(());
    }
    let exp = scenario.faults.check_expects(report);
    if !exp.is_empty() {
        status(quiet, &exp.render_text());
    }
    exp.fail_on_errors("fault expectations")?;
    status(
        quiet,
        &format!("expectations OK: {} clause(s)", scenario.faults.expects.len()),
    );
    Ok(())
}

/// `sparseloom bench` — the fleet-scale throughput sweep. Hermetic by
/// construction (it runs on [`fixtures::fleet`]), it drives the same
/// Poisson stream through every `(shard count, threaded?)` arm on the
/// static sharded path with event retention off, reports wall-clock and
/// queries/s per arm, and records the threaded-vs-sequential speedup
/// per shard count. The JSON it writes feeds the CI tier-2 regression
/// gate (`--gate benchmarks/BENCH_fleet.baseline.json`).
fn cmd_bench(args: &sparseloom::cli::Args) -> Result<()> {
    let n_tasks = args.get_usize("tasks")?.unwrap_or(16).max(1);
    let rate = args.get_f64("rate-qps")?.unwrap_or(40.0);
    let horizon = args.get_f64("horizon-ms")?.unwrap_or(3_000.0);
    let iters = args.get_usize("iters")?.unwrap_or(3).max(1);
    let tolerance = args.get_f64("tolerance")?.unwrap_or(0.2).clamp(0.0, 1.0);
    let mut shard_counts = Vec::new();
    for part in args.get_or("shards", "1,2,4").split(',') {
        let s: usize = part.trim().parse().map_err(|_| {
            anyhow::anyhow!("--shards wants comma-separated integers, got {part:?}")
        })?;
        shard_counts.push(s.max(1));
    }
    let (zoo, lm, profiles, _) = fixtures::fleet(1, n_tasks);
    let tasks = fixtures::task_names(&zoo);
    // Loose SLOs: the bench measures drive throughput, not violations.
    let slos = fixtures::slos(&zoo, 0.5, 1e9);
    println!(
        "bench fleet: {n_tasks} tasks | {rate:.0} qps/task | horizon {horizon:.0} ms | \
         shards {shard_counts:?} | best of {iters}"
    );
    let mut arms = Vec::new();
    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for &s in &shard_counts {
        let mut seq_wall = f64::NAN;
        for parallel in [false, true] {
            if parallel && s < 2 {
                // One shard: the threaded drive degenerates to the
                // sequential loop, so the arm would be a duplicate.
                continue;
            }
            let scenario = Scenario::poisson(&tasks, slos.clone(), rate, horizon)
                .with_dispatch(Dispatch { max_batch: 4, min_queue: 2 })
                .with_sharding(Sharding::hash(s))
                .with_seed(7);
            let opts = ServeOpts {
                // Streaming aggregates only: retention stays O(1) in
                // request count, which is itself a benched property.
                record_events: false,
                parallel,
                ..Default::default()
            };
            let sharded =
                ShardedServer::build(&zoo, &lm, &profiles, opts, scenario.sharding.clone())?;
            let _ = sharded.run(&scenario)?; // warmup: plan caches
            let (wall_ms, report) =
                sparseloom::benchkit::time_best_of(iters, || sharded.run(&scenario));
            let report = report?;
            let total = report.aggregate.total_queries;
            let retained = report.aggregate.requests.len()
                + report.per_shard.iter().map(|p| p.requests.len()).sum::<usize>();
            let qps = if wall_ms > 0.0 { total as f64 / (wall_ms / 1e3) } else { 0.0 };
            let mut arm = vec![
                ("shards", Json::Num(s as f64)),
                ("parallel", Json::Bool(parallel)),
                ("wall_ms", Json::Num(wall_ms)),
                ("bench_qps", Json::Num(qps)),
                ("virtual_qps", Json::Num(report.aggregate.throughput_qps())),
                ("total_queries", Json::Num(total as f64)),
                ("events_retained", Json::Num(retained as f64)),
            ];
            if parallel {
                let speedup = seq_wall / wall_ms;
                speedups.push((s, speedup));
                arm.push(("speedup_vs_single", Json::Num(speedup)));
                println!(
                    "  {s:>2} shard(s) threaded:   {wall_ms:>9.2} ms wall | {qps:>10.0} q/s \
                     | {speedup:.2}x vs single-thread | {retained} event(s) retained"
                );
            } else {
                seq_wall = wall_ms;
                println!(
                    "  {s:>2} shard(s) sequential: {wall_ms:>9.2} ms wall | {qps:>10.0} q/s \
                     | {retained} event(s) retained"
                );
            }
            arms.push(Json::obj(arm));
        }
    }
    let out = Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("tasks", Json::Num(n_tasks as f64)),
                ("rate_qps", Json::Num(rate)),
                ("horizon_ms", Json::Num(horizon)),
                ("iters", Json::Num(iters as f64)),
                ("seed", Json::Num(7.0)),
            ]),
        ),
        ("arms", Json::arr(arms)),
        (
            "speedup_vs_single",
            Json::Obj(
                speedups
                    .iter()
                    .map(|(s, x)| (s.to_string(), Json::Num(*x)))
                    .collect(),
            ),
        ),
    ]);
    let path = args.get_or("out", "BENCH_fleet.json");
    std::fs::write(&path, out.to_string_pretty())?;
    println!("wrote {path}");
    if let Some(gate) = args.get("gate") {
        let text = std::fs::read_to_string(gate)
            .map_err(|e| anyhow::anyhow!("gate baseline {gate}: {e}"))?;
        let baseline = sparseloom::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("gate baseline {gate}: {e}"))?;
        gate_speedups(&speedups, &baseline, tolerance)?;
        println!(
            "throughput gate OK vs {gate} (tolerance {:.0} %)",
            100.0 * tolerance
        );
        gate_trace_overhead(&zoo, &lm, &profiles, &tasks, &slos, rate, horizon, iters, tolerance)?;
    }
    Ok(())
}

/// Tracing must be effectively free: time the same sequential arm with
/// the sink off and on (best of `iters` each, warmup excluded) and
/// hold the traced slowdown to the throughput gate's fractional
/// tolerance.
#[allow(clippy::too_many_arguments)]
fn gate_trace_overhead(
    zoo: &Zoo,
    lm: &sparseloom::soc::LatencyModel,
    profiles: &BTreeMap<String, sparseloom::profiler::TaskProfile>,
    tasks: &[String],
    slos: &BTreeMap<String, sparseloom::workload::Slo>,
    rate: f64,
    horizon: f64,
    iters: usize,
    tolerance: f64,
) -> Result<()> {
    let scenario = Scenario::poisson(tasks, slos.clone(), rate, horizon)
        .with_dispatch(Dispatch { max_batch: 4, min_queue: 2 })
        .with_sharding(Sharding::hash(2))
        .with_seed(7);
    let mut walls = [0.0f64; 2];
    for (slot, traced) in [(0usize, false), (1usize, true)] {
        let opts = ServeOpts {
            record_events: false,
            parallel: false,
            trace: traced,
            ..Default::default()
        };
        let sharded =
            ShardedServer::build(zoo, lm, profiles, opts, scenario.sharding.clone())?;
        let _ = sharded.run(&scenario)?; // warmup: plan caches
        let (wall_ms, report) =
            sparseloom::benchkit::time_best_of(iters, || sharded.run(&scenario));
        report?;
        walls[slot] = wall_ms;
    }
    let overhead = if walls[0] > 0.0 { walls[1] / walls[0] - 1.0 } else { 0.0 };
    println!(
        "  trace arm: {:.2} ms untraced vs {:.2} ms traced ({:+.1} %)",
        walls[0],
        walls[1],
        100.0 * overhead
    );
    if overhead > tolerance {
        bail!(
            "trace overhead gate failed: traced run {:.1} % slower than untraced \
             (tolerance {:.0} %)",
            100.0 * overhead,
            100.0 * tolerance
        );
    }
    println!("trace overhead gate OK (tolerance {:.0} %)", 100.0 * tolerance);
    Ok(())
}

/// Gate measured threaded speedups against a committed baseline: each
/// `speedup_vs_single` entry may undershoot its baseline value by at
/// most `tolerance` (fractional). The committed baseline records
/// conservative floors, so the gate catches "threading stopped
/// helping" regressions without flaking on slower CI machines.
fn gate_speedups(speedups: &[(usize, f64)], baseline: &Json, tolerance: f64) -> Result<()> {
    let base = baseline
        .get("speedup_vs_single")
        .and_then(|s| s.as_obj())
        .ok_or_else(|| anyhow::anyhow!("gate baseline has no speedup_vs_single object"))?;
    let mut failures = Vec::new();
    for (s, measured) in speedups {
        if let Some(want) = base.get(&s.to_string()).and_then(|v| v.as_f64()) {
            let floor = want * (1.0 - tolerance);
            if *measured < floor {
                failures.push(format!(
                    "{s} shard(s): speedup {measured:.2}x < floor {floor:.2}x \
                     (baseline {want:.2}x - {:.0} %)",
                    100.0 * tolerance
                ));
            } else {
                println!("  gate {s} shard(s): {measured:.2}x >= floor {floor:.2}x");
            }
        }
    }
    if !failures.is_empty() {
        bail!(
            "throughput regression gate failed:\n  {}",
            failures.join("\n  ")
        );
    }
    Ok(())
}

fn cmd_lint(args: &sparseloom::cli::Args) -> Result<()> {
    if args.positional.is_empty() {
        bail!("usage: sparseloom lint <scenario.json>... [--fixture] [--json]");
    }
    // Pass group 3 (plan/stitch feasibility) needs a concrete zoo.
    // `--fixture` lints against the hermetic in-memory quartet — the CI
    // path; otherwise artifacts are used when they load, and the pass
    // is skipped with a note when they do not.
    let feas = if args.switch("fixture") {
        Some(fixtures::quartet())
    } else {
        match Ctx::load(&args.get_or("artifacts", "artifacts"), args.switch("synthetic")) {
            Ok(ctx) => {
                let platform = Platform::by_name(&args.get_or("platform", "desktop"))?;
                let lm = ctx.lm(platform.clone());
                let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;
                Some((ctx.zoo_for(&platform).clone(), lm, profiles))
            }
            Err(_) => None,
        }
    };

    let json_out = args.switch("json");
    let mut any_errors = false;
    let mut per_file = Vec::new();
    for path in &args.positional {
        let report = match Scenario::load(path) {
            Ok(sc) => {
                let mut r = analysis::lint_scenario(&sc);
                match &feas {
                    Some((zoo, lm, profiles)) => r.merge(analysis::lint_feasibility(
                        &sc,
                        zoo,
                        lm,
                        profiles,
                        &ServeOpts::default(),
                    )),
                    None => r.push(analysis::Diagnostic::info(
                        "SL-FEA-008",
                        "probe",
                        "zoo probe skipped: no artifacts loaded (pass --fixture, or point \
                         --artifacts at a built zoo)",
                    )),
                }
                r
            }
            // A file that does not even load as a Scenario is itself a
            // finding, never a crash (the corrupted-corpus contract).
            Err(e) => {
                let mut r = analysis::Report::new();
                r.push(analysis::Diagnostic::error(
                    "SL-SCN-000",
                    path.as_str(),
                    format!("not a loadable scenario: {e:#}"),
                ));
                r
            }
        };
        any_errors |= report.has_errors();
        if json_out {
            per_file.push(Json::obj(vec![
                ("file", Json::Str(path.clone())),
                ("report", report.to_json()),
            ]));
        } else {
            println!("== {path}");
            println!("{}", report.render_text());
        }
    }
    if json_out {
        println!("{}", Json::arr(per_file).to_string_pretty());
    }
    if any_errors {
        bail!("lint found Error-level diagnostics");
    }
    println!("lint OK: {} file(s) free of errors", args.positional.len());
    Ok(())
}

/// `sparseloom explain <trace>` — the SLO-violation attribution tool.
///
/// A JSONL trace (the `serve --trace` default) is linted
/// (`SL-TRC-001..003`) and every violation attributed to its dominant
/// cause bucket; a Chrome trace-event document (`--trace-format
/// chrome`) is structurally validated — it carries rendering records
/// (flow arrows, track metadata), not the replayable event stream, so
/// attribution asks for the JSONL form.
fn cmd_explain(args: &sparseloom::cli::Args) -> Result<()> {
    if args.positional.len() != 1 {
        bail!("usage: sparseloom explain <run.trace.jsonl | run.trace.json>");
    }
    let path = &args.positional[0];
    let text =
        std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    // A Chrome document parses as ONE JSON value with a `traceEvents`
    // array; a JSONL trace is one object per line (a single-line JSONL
    // file parses whole too, but has no `traceEvents` key).
    if let Ok(doc) = sparseloom::json::parse(&text) {
        if let Some(recs) = doc.get("traceEvents").and_then(|e| e.as_arr()) {
            for (i, r) in recs.iter().enumerate() {
                let well_formed = r.get("ph").and_then(|p| p.as_str()).is_some()
                    && r.get("pid").and_then(|p| p.as_f64()).is_some()
                    && r.get("tid").and_then(|p| p.as_f64()).is_some();
                if !well_formed {
                    bail!("{path}: traceEvents[{i}] is not a well-formed trace record");
                }
            }
            println!("chrome trace OK ({} record(s))", recs.len());
            println!(
                "note: attribution replays the JSONL trace (serve --trace out.jsonl); \
                 the Chrome document is for timeline viewers"
            );
            return Ok(());
        }
    }
    let (events, lint) = trace::parse_jsonl(&text);
    if !lint.is_empty() {
        println!("{}", lint.render_text());
    }
    lint.fail_on_errors("trace")?;
    let attribution = trace::explain::attribute(&events);
    println!("{}", trace::explain::render(&attribution));
    Ok(())
}

/// Per-task projected SLO violation rates (worst shard fragment), when
/// the run produced any.
fn print_forecast(report: &RunReport) {
    if report.slo_forecast.is_empty() {
        return;
    }
    let parts: Vec<String> = report
        .slo_forecast
        .iter()
        .map(|(task, p)| format!("{task} {:.0}%", 100.0 * p))
        .collect();
    println!("  slo forecast (next horizon): {}", parts.join(" | "));
}

fn print_outcomes(report: &RunReport) {
    for o in &report.outcomes {
        println!(
            "  {:<10} acc={:<6} mean={:.3} ms p50={:.3} p95={:.3} p99={:.3} queue={:.3} ms \
             done={} drop={} slo=({:.3}, {:.2} ms) {}",
            o.task,
            o.accuracy.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".into()),
            o.mean_latency_ms,
            o.p50_latency_ms,
            o.p95_latency_ms,
            o.p99_latency_ms,
            o.mean_queueing_ms,
            o.queries_completed,
            o.queries_dropped,
            o.slo_accuracy,
            o.slo_latency_ms,
            if o.violated() { "VIOLATED" } else { "ok" },
        );
    }
}

fn print_summary(report: &RunReport) {
    println!(
        "violation rate: {:.1} % | throughput: {:.1} q/s | makespan {:.1} ms | dropped {} \
         | mean batch {:.2} | fairness {:.3}",
        100.0 * report.violation_rate(),
        report.throughput_qps(),
        report.makespan_ms,
        report.total_dropped,
        report.mean_batch_size(),
        report.fairness_index(),
    );
}

fn cmd_exp(args: &sparseloom::cli::Args) -> Result<()> {
    // Hermetic path first: `exp backlog --fixture` runs the backlog
    // study on the in-memory fixture zoo, before any artifact load —
    // the CI smoke stage exercises exactly this.
    let json_out = args.switch("json");
    if args.switch("fixture") {
        if !args.positional.iter().all(|p| p == "backlog") || args.positional.is_empty()
        {
            bail!("--fixture supports only `exp backlog` (got {:?})", args.positional);
        }
        let horizon_ms = args.get_f64("horizon-ms")?.unwrap_or(6_000.0);
        let (zoo, lm, profiles) = fixtures::quartet();
        if json_out {
            let doc = experiments::endtoend::backlog_comparison_json(
                &zoo, &lm, &profiles, horizon_ms,
            )?;
            println!("{}", doc.to_string_pretty());
        } else {
            let out = experiments::endtoend::backlog_comparison(
                &zoo, &lm, &profiles, horizon_ms,
            )?;
            println!("{out}");
        }
        return Ok(());
    }
    if json_out && args.positional != ["backlog"] {
        bail!("--json supports only `exp backlog` (got {:?})", args.positional);
    }
    let ctx = Ctx::load(&args.get_or("artifacts", "artifacts"), args.switch("synthetic"))?;
    let ids: Vec<String> = if args.positional.is_empty()
        || args.positional.iter().any(|p| p == "all")
    {
        experiments::ALL.iter().map(|s| s.to_string()).collect()
    } else {
        args.positional.clone()
    };
    let horizon_ms = args.get_f64("horizon-ms")?.unwrap_or(6_000.0);
    for id in &ids {
        // The backlog study honors --horizon-ms on this path too.
        if json_out && id == "backlog" {
            println!(
                "{}",
                experiments::endtoend::backlog_json_with(&ctx, horizon_ms)?
                    .to_string_pretty()
            );
            continue;
        }
        let out = if id == "backlog" {
            experiments::endtoend::backlog_with(&ctx, horizon_ms)?
        } else {
            experiments::run(&ctx, id)?
        };
        println!("{out}");
        println!("{}", "=".repeat(78));
    }
    Ok(())
}

fn cmd_profile(args: &sparseloom::cli::Args) -> Result<()> {
    let ctx = Ctx::load(&args.get_or("artifacts", "artifacts"), args.switch("synthetic"))?;
    let platform = Platform::by_name(&args.get_or("platform", "desktop"))?;
    let lm = ctx.lm(platform.clone());
    let cfg = ProfilerConfig {
        train_samples: args.get_usize("train-samples")?.unwrap_or(80),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let profiles = ctx.profiles(&lm, &cfg)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("profiled {} tasks in {dt:.2} s on {}", profiles.len(), platform.name);
    let orders = sparseloom::workload::placement_orders(&platform, ctx.zoo.subgraphs);
    for (name, p) in &profiles {
        let rep = sparseloom::profiler::evaluate_estimators(p, &orders, &[10], 300, 3);
        println!(
            "  {:<10} V^S={} | train={} | R@10={:.1} % | lat MAE {:.3} ms MAPE {:.1} %",
            name,
            p.space.len(),
            p.train_indices.len(),
            100.0 * rep.recall_at[0].1,
            rep.lat_mae_ms,
            rep.lat_mape_pct,
        );
    }
    Ok(())
}

fn cmd_calibrate(args: &sparseloom::cli::Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let zoo = Zoo::load(&artifacts)?;
    let rt = Runtime::new()?;
    let iters = args.get_usize("iters")?.unwrap_or(30);
    let t0 = std::time::Instant::now();
    let base = experiments::measure_base_latencies(&zoo, &rt, iters)?;
    println!(
        "measured {} (task, sg, path) latencies in {:.1} s on PJRT {}",
        base.len(),
        t0.elapsed().as_secs_f64(),
        rt.platform_name(),
    );
    let cache = std::path::Path::new(&artifacts).join("base_latencies.json");
    // Reuse the experiments writer by round-tripping through Ctx.
    super_write(&cache, &base, &zoo)?;
    println!("wrote {}", cache.display());
    Ok(())
}

fn super_write(
    path: &std::path::Path,
    base: &sparseloom::soc::BaseLatencies,
    zoo: &Zoo,
) -> Result<()> {
    use sparseloom::json::Json;
    use sparseloom::zoo::KernelPath;
    let mut entries = Vec::new();
    for (tname, tz) in &zoo.tasks {
        let mut paths: Vec<KernelPath> =
            tz.variants.iter().map(|x| x.spec.kernel_path).collect();
        paths.sort();
        paths.dedup();
        for sg in 0..zoo.subgraphs {
            for &p in &paths {
                if let Ok(ms) = base.get(tname, sg, p) {
                    entries.push(Json::obj(vec![
                        ("task", Json::Str(tname.clone())),
                        ("sg", Json::Num(sg as f64)),
                        ("path", Json::Str(p.name().to_string())),
                        ("ms", Json::Num(ms)),
                    ]));
                }
            }
        }
    }
    std::fs::write(path, Json::arr(entries).to_string_pretty())?;
    Ok(())
}

fn cmd_probe(args: &sparseloom::cli::Args) -> Result<()> {
    let zoo = Zoo::load(args.get_or("artifacts", "artifacts"))?;
    let tol = args.get_f64("tolerance")?.unwrap_or(0.002) as f32;
    let rt = Runtime::new()?;
    let mut worst = 0f32;
    for (tname, tz) in &zoo.tasks {
        let (x, expected) = zoo.load_probe(tname)?;
        for (vi, want) in expected.iter().enumerate() {
            let comp = vec![vi; zoo.subgraphs];
            // Probe batch may differ from compiled batch sizes; pad to
            // the smallest compiled batch that fits.
            let batch = *zoo
                .batch_sizes
                .iter()
                .filter(|&&b| b >= zoo.probe_batch)
                .min()
                .unwrap_or(&zoo.probe_batch);
            let d = tz.input_dim;
            let mut input = vec![0f32; batch * d];
            input[..zoo.probe_batch * d].copy_from_slice(&x);
            let (got, _) = rt.run_chain(&zoo, tname, &comp, batch, &input)?;
            for r in 0..zoo.probe_batch {
                for c in 0..zoo.n_classes {
                    let g = got[r * zoo.n_classes + c];
                    let w = want[r * zoo.n_classes + c];
                    let d = (g - w).abs();
                    if d > worst {
                        worst = d;
                    }
                    if d > tol {
                        bail!(
                            "{tname} variant {vi} row {r} class {c}: got {g}, want {w} (|Δ|={d} > {tol})"
                        );
                    }
                }
            }
        }
        println!("  {tname}: all {} variants match python expectations", tz.variants.len());
    }
    println!("probe OK (worst |Δlogit| = {worst:.2e}, tolerance {tol})");
    Ok(())
}

fn cmd_zoo(args: &sparseloom::cli::Args) -> Result<()> {
    let zoo = Zoo::load(args.get_or("artifacts", "artifacts"))?;
    println!(
        "zoo {:?}: {} tasks × {} variants × {} subgraphs (seed {})",
        zoo.zoo_name,
        zoo.tasks.len(),
        zoo.n_variants(),
        zoo.subgraphs,
        zoo.seed,
    );
    for (name, tz) in &zoo.tasks {
        println!("  {name} ({}, input {}d, iface {:?})", tz.family, tz.input_dim, tz.iface);
        for v in &tz.variants {
            println!(
                "    {:<10} {:<13} sparsity {:>3.0} % acc {:.3} {:>10}",
                v.spec.name,
                v.spec.vtype.name(),
                100.0 * v.spec.sparsity,
                v.accuracy,
                sparseloom::util::fmt_bytes(v.total_bytes()),
            );
        }
    }
    Ok(())
}
