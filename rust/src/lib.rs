//! # SparseLoom
//!
//! Reproduction of *"Multi-DNN Inference of Sparse Models on Edge SoCs"*
//! (CS.DC 2026) as a three-layer Rust + JAX + Pallas system.
//!
//! - **L1 (build time)** — Pallas sparse-matmul kernels
//!   (`python/compile/kernels/`), validated against a pure-jnp oracle.
//! - **L2 (build time)** — four task models partitioned into S=3
//!   layer-aligned subgraphs, AOT-lowered to HLO text per
//!   (subgraph, kernel-path, batch); weights serialized per variant.
//! - **L3 (this crate)** — the serving system: model stitching over the
//!   sparse zoo, estimator-based profiling, sparsity-aware placement,
//!   hot-subgraph preloading, and a scenario-driven server
//!   (`scenario::Server` over the planning `coordinator`) executing
//!   stitched variants through PJRT under closed-loop, Poisson
//!   open-loop, bursty, or traced arrivals.
//!
//! See `DESIGN.md` for the architecture and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

// Project style lives in the workspace `[lints]` tables (Cargo.toml):
// unsafe is forbidden crate-wide, and the two clippy allowances
// (index-driven loops mirroring the paper's math, nested-map result
// shapes) are declared there so `cargo clippy -D warnings`
// (scripts/ci.sh) and plain builds agree on the posture.

pub mod analysis;
pub mod baselines;
pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod exec;
pub mod experiments;
pub mod fixtures;
pub mod gbdt;
pub mod json;
pub mod metrics;
pub mod optimizer;
pub mod planner;
pub mod preloader;
pub mod profiler;
pub mod propcheck;
pub mod runtime;
pub mod scenario;
pub mod soc;
pub mod stitching;
pub mod telemetry;
pub mod trace;
pub mod util;
pub mod workload;
pub mod zoo;

/// Default artifact directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
