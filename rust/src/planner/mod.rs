//! Unified planner subsystem.
//!
//! Before this module, planning was spread over three code paths:
//! Algorithm 1 in `optimizer`, Algorithm 2 memory planning in
//! `preloader`, and the plan-assembly glue inside
//! `Coordinator::prepare_with_pool`. The planner unifies them behind
//! one contract — a [`PlanContext`] in, a [`Plan`] out — with an
//! explicit, batch-aware [`CostModel`] and an incremental
//! [`Planner::replan`] entry point for online re-sharding:
//!
//! ```text
//! telemetry::Telemetry ──▶ PlanContext { slos, arrival_hint, batch_hint,
//!      │       (plan_context)            memory_budget, Ψ }
//!      │                       │
//!      │                       ▼ Planner::plan
//!      │              CostModel (latency_est_batch × batch_factor)
//!      │                   ├─ algo::optimize_weighted  — Algorithm 1
//!      │                   └─ memory::{split_budget_by_hotness_weighted,
//!      │                              preload} — Algorithm 2
//!      │                       ▼
//!      │              Plan { order, selections, preload, task_budgets }
//!      │
//!      └─▶ saturation (scenario::dispatch) ──▶ Planner::replan(prior, observed)
//!                              ▼
//!          Migration { hottest movable task (Eq. 7 mass × observed qps)
//!                      → least-loaded shard, variant re-selected under
//!                      its traffic-weighted budget share }
//! ```
//!
//! `arrival_hint` no longer needs to be hand-supplied: the serving
//! layer's `telemetry::Telemetry` estimates it online (EWMA + sliding
//! window). The online drive feeds the estimates into `replan` via
//! `ShardObservation::arrival_qps` on every saturation event;
//! `Telemetry::plan_context` is the corresponding front door for
//! callers re-running the *full* `Planner::plan` from observed traffic
//! (there is nothing to observe at first-prepare time, so startup
//! plans stay unweighted). Hand-set hints remain possible for offline
//! what-if planning.
//!
//! Variant answers flow through one more seam: a [`VariantProvider`]
//! (see [`provider`]) owns the "which stitched index serves this task?"
//! question for `plan`, `replan`, the steal/warm-migrate adoption path,
//! and the online synthesis action. The default provider reproduces
//! Algorithm 1's enumerated selection bit-for-bit;
//! [`SparsityAwarePlanner::with_synthesis`] swaps in the bounded
//! best-first synthesizer (DESIGN.md §Stitching).
//!
//! The pre-planner entry points (`optimizer::optimize`,
//! `optimizer::feasible_set`, `preloader::preload`) are gone —
//! `planner::algo` and `planner::memory` are the only implementations.
//! See DESIGN.md §Planner for the data flow and the shard-migration
//! invariant.

pub mod algo;
pub mod cost;
pub mod memory;
pub mod provider;
pub mod replan;

pub use cost::CostModel;
pub use provider::{
    PressureSignal, SearchStats, VariantDecision, VariantProvider, VariantQuery,
    VariantSource,
};
pub use replan::{Migration, ShardObservation, ShardPlan};

use std::collections::BTreeMap;

use anyhow::Result;

use crate::optimizer::Selection;
use crate::preloader::{Hotness, PreloadPlan};
use crate::profiler::TaskProfile;
use crate::soc::{LatencyModel, Processor};
use crate::workload::{placement_orders, Slo};
use crate::zoo::{TaskZoo, Zoo};

/// Everything a planner needs to commit a deployment plan.
#[derive(Clone, Debug)]
pub struct PlanContext {
    /// The SLO configuration to plan for (one entry per served task).
    pub slos: BTreeMap<String, Slo>,
    /// The SLO universe Ψ hotness is scored over (empty ⇒ the SLO
    /// configuration itself).
    pub universe: Vec<Slo>,
    /// Per-task arrival rate (qps) — step 2's placement objective and
    /// the budget split weight tasks by it (missing tasks weigh 1.0;
    /// empty map = the paper's unweighted mean). Fed automatically by
    /// `telemetry::Telemetry::plan_context` from the live EWMA
    /// estimators; set it by hand only for offline what-if planning.
    pub arrival_hint: BTreeMap<String, f64>,
    /// Expected mean coalesced batch size per task (overrides
    /// `default_batch_hint`).
    pub batch_hint: BTreeMap<String, f64>,
    /// Default expected batch size (1.0 = the paper's batch-1 planning).
    pub default_batch_hint: f64,
    /// Memory budget (bytes) for Algorithm 2 preloading.
    pub memory_budget: u64,
}

impl PlanContext {
    /// Batch-1, unweighted context — the paper's planning regime.
    pub fn new(slos: BTreeMap<String, Slo>, memory_budget: u64) -> PlanContext {
        PlanContext {
            slos,
            universe: Vec::new(),
            arrival_hint: BTreeMap::new(),
            batch_hint: BTreeMap::new(),
            default_batch_hint: 1.0,
            memory_budget,
        }
    }

    pub fn with_universe(mut self, universe: Vec<Slo>) -> PlanContext {
        self.universe = universe;
        self
    }

    pub fn with_arrival_hint(mut self, hint: BTreeMap<String, f64>) -> PlanContext {
        self.arrival_hint = hint;
        self
    }

    pub fn with_batch_hint(mut self, hint: BTreeMap<String, f64>) -> PlanContext {
        self.batch_hint = hint;
        self
    }

    pub fn with_default_batch_hint(mut self, hint: f64) -> PlanContext {
        self.default_batch_hint = hint.max(1.0);
        self
    }

    /// The effective hotness universe: Ψ if set, else the SLO map's
    /// own configurations.
    pub fn effective_universe(&self) -> Vec<Slo> {
        if self.universe.is_empty() {
            self.slos.values().copied().collect()
        } else {
            self.universe.clone()
        }
    }
}

/// The committed plan: Algorithm 1's joint decision plus the memory
/// plan and the hotness-proportional per-task budget split.
#[derive(Clone, Debug)]
pub struct Plan {
    /// p⃗* — the global placement order.
    pub order: Vec<Processor>,
    /// Per task: chosen stitched index + batch-aware latency estimate,
    /// or `None` when Θᵗ was empty.
    pub selections: BTreeMap<String, Option<Selection>>,
    /// The (arrival-weighted) mean best latency under p⃗*.
    pub mean_latency_ms: f64,
    /// Algorithm 2 preload plan under `PlanContext::memory_budget`.
    pub preload: PreloadPlan,
    /// Hotness-proportional split of the memory budget across tasks.
    pub task_budgets: BTreeMap<String, u64>,
}

/// A planner maps a [`PlanContext`] to a [`Plan`] up-front, and revises
/// a sharded deployment incrementally when the dispatcher observes
/// saturation.
pub trait Planner {
    /// Full planning: joint placement + variant selection + memory plan.
    fn plan(&self, ctx: &PlanContext) -> Result<Plan>;

    /// Bounded online re-plan: one task migration (or `None` when no
    /// move helps). Invoked by `scenario::dispatch` when a shard's
    /// backlog crosses its saturation threshold. Implementations must
    /// never reorder queries within a task — they only *relocate*
    /// future queries, and the serving layer floors the migrant's start
    /// at its old shard's last completion.
    fn replan(&self, prior: &ShardPlan, observed: &ShardObservation) -> Option<Migration>;
}

/// The sparsity-aware planner: Algorithm 1 (batch-aware, pruned) +
/// Algorithm 2 (hotness budgets) + hotness-driven migration.
pub struct SparsityAwarePlanner<'a> {
    zoo: &'a Zoo,
    lm: &'a LatencyModel,
    profiles: &'a BTreeMap<String, TaskProfile>,
    orders: Vec<Vec<Processor>>,
    /// Per-task hotness, computed lazily on the first `replan` and
    /// reused for victim scoring, budget splits, and re-selection —
    /// the Eq. 7 walk is |Ψ| × V^S, far too hot to rerun per
    /// saturation event. One planner instance assumes one Ψ (true for
    /// the replan drive, which builds a planner per run).
    hotness_cache: std::cell::RefCell<BTreeMap<String, Hotness>>,
    /// The variant answer mode: enumerated by default, the bounded
    /// best-first synthesizer after [`Self::with_synthesis`].
    provider: Box<dyn VariantProvider + 'a>,
}

impl<'a> SparsityAwarePlanner<'a> {
    pub fn new(
        zoo: &'a Zoo,
        lm: &'a LatencyModel,
        profiles: &'a BTreeMap<String, TaskProfile>,
    ) -> SparsityAwarePlanner<'a> {
        let orders = placement_orders(&lm.platform, zoo.subgraphs);
        let provider: Box<dyn VariantProvider + 'a> = Box::new(
            provider::EnumeratedProvider::new(zoo, lm, profiles, orders.clone()),
        );
        SparsityAwarePlanner {
            zoo,
            lm,
            profiles,
            orders,
            hotness_cache: std::cell::RefCell::new(BTreeMap::new()),
            provider,
        }
    }

    /// Switch the planner's variant answers to the synthesizing
    /// provider: ordinary (pressure-free) queries stay bit-identical to
    /// the enumerated path; queries carrying a [`PressureSignal`] run
    /// the bounded best-first stitch search with per-operating-point
    /// caching.
    pub fn with_synthesis(mut self) -> SparsityAwarePlanner<'a> {
        self.provider = Box::new(provider::SynthesizingProvider::new(
            self.zoo,
            self.lm,
            self.profiles,
            self.orders.clone(),
        ));
        self
    }

    /// The variant provider answering this planner's selection queries.
    pub fn provider(&self) -> &dyn VariantProvider {
        self.provider.as_ref()
    }

    /// The order set Ω this planner optimizes over.
    pub fn orders(&self) -> &[Vec<Processor>] {
        &self.orders
    }

    /// Cached Eq. 7 hotness of one task over `universe`.
    fn hotness_of(&self, name: &str, universe: &[Slo]) -> Option<Hotness> {
        if let Some(h) = self.hotness_cache.borrow().get(name) {
            return Some(h.clone());
        }
        let p = self.profiles.get(name)?;
        let h = Hotness::compute(p, universe, &self.orders);
        self.hotness_cache
            .borrow_mut()
            .insert(name.to_string(), h.clone());
        Some(h)
    }

    fn cost_model(&self, ctx: &PlanContext) -> CostModel {
        CostModel::batch_aware(self.lm, ctx.default_batch_hint)
            .with_hints(ctx.batch_hint.clone())
    }

    /// (task zoo, hotness) pairs for the tasks in `slos`, scored over
    /// `universe` (served from the per-instance cache).
    fn hotness_pairs(
        &self,
        slos: &BTreeMap<String, Slo>,
        universe: &[Slo],
    ) -> Result<Vec<(&'a TaskZoo, Hotness)>> {
        let mut pairs = Vec::new();
        for name in self.profiles.keys() {
            if !slos.contains_key(name) {
                continue;
            }
            let tz = self.zoo.task(name)?;
            let Some(h) = self.hotness_of(name, universe) else { continue };
            pairs.push((tz, h));
        }
        Ok(pairs)
    }

    /// Re-select the migrant's variant **against the target shard's
    /// committed placement order** (a variant feasible somewhere in Ω
    /// may be unsupported or SLO-infeasible on the order the target
    /// actually serves under): batch-aware feasible set, then the
    /// fastest candidate whose weights fit the task's traffic-weighted
    /// hotness share of the target shard's pool (fallback: fastest
    /// feasible regardless of share — the pool evicts colder blobs at
    /// load time). Also used by the stealing drive to pick the thief's
    /// serving variant at adoption.
    pub(crate) fn reselect(
        &self,
        task: &str,
        prior: &ShardPlan,
        observed: &ShardObservation,
        to: usize,
    ) -> Option<Selection> {
        let slo = prior.slos.get(task)?;
        // The target's committed order when known; full Ω otherwise
        // (an empty feasible set defers to the provider's Ω).
        let feasible_orders: Vec<Vec<Processor>> = match observed.shard_orders.get(to) {
            Some(order) if !order.is_empty() => vec![order.clone()],
            _ => Vec::new(),
        };

        // Budget split by hotness over the target shard's new tenant
        // set (its current tasks plus the migrant), out of the target's
        // pool capacity.
        let mut names: Vec<String> = prior
            .assignment
            .iter()
            .filter(|&(_, s)| *s == to)
            .map(|(n, _)| n.clone())
            .collect();
        if !names.iter().any(|n| n == task) {
            names.push(task.to_string());
        }
        let target_pool = observed.shard_pool_bytes.get(to).copied().unwrap_or(0);
        let share = self.share_of(task, &names, target_pool, &prior.universe, &observed.arrival_qps);

        let q = VariantQuery {
            task: task.to_string(),
            slo: *slo,
            feasible_orders,
            commit_order: None,
            batch: observed.mean_batch.get(task).copied().unwrap_or(1.0),
            pool_share: share,
            phase: 0,
            pressure: None,
        };
        self.provider.provide(&q).map(|d| d.selection)
    }

    /// The task's traffic-weighted hotness share of a `pool_bytes`
    /// budget split across `tenants` (the `reselect` budget rule,
    /// shared with the synthesis action).
    fn share_of(
        &self,
        task: &str,
        tenants: &[String],
        pool_bytes: u64,
        universe: &[Slo],
        arrival_qps: &BTreeMap<String, f64>,
    ) -> u64 {
        let mut pairs: Vec<(&TaskZoo, Hotness)> = Vec::new();
        for name in tenants {
            let Ok(ntz) = self.zoo.task(name) else { continue };
            let Some(h) = self.hotness_of(name, universe) else { continue };
            pairs.push((ntz, h));
        }
        let refs: Vec<(&TaskZoo, &Hotness)> =
            pairs.iter().map(|(ntz, h)| (*ntz, h)).collect();
        let budgets =
            memory::split_budget_by_hotness_weighted(&refs, pool_bytes, arrival_qps);
        budgets.get(task).copied().unwrap_or(0)
    }

    /// The online synthesis action: price the incumbent and answer a
    /// pressure-mode variant query for `task` at its live operating
    /// point. `tenants` are the tasks sharing the home shard's pool
    /// (including `task`); the pool share follows the same
    /// traffic-weighted hotness split as `reselect`. Returns the
    /// provider's decision plus the incumbent's score under the same
    /// query (for the caller's switch-margin test).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn synthesize(
        &self,
        task: &str,
        slo: &Slo,
        universe: &[Slo],
        tenants: &[String],
        pool_bytes: u64,
        commit_order: Option<Vec<Processor>>,
        batch: f64,
        arrival_qps: &BTreeMap<String, f64>,
        phase: usize,
        pressure: PressureSignal,
        incumbent: Option<usize>,
    ) -> Option<(VariantDecision, Option<Selection>)> {
        let share = self.share_of(task, tenants, pool_bytes, universe, arrival_qps);
        let q = VariantQuery {
            task: task.to_string(),
            slo: *slo,
            feasible_orders: Vec::new(),
            commit_order,
            batch,
            pool_share: share,
            phase,
            pressure: Some(pressure),
        };
        let scored = incumbent.and_then(|k| self.provider.score(&q, k));
        let dec = self.provider.provide(&q)?;
        Some((dec, scored))
    }
}

impl Planner for SparsityAwarePlanner<'_> {
    fn plan(&self, ctx: &PlanContext) -> Result<Plan> {
        let cost = self.cost_model(ctx);
        let alg1 = algo::optimize_weighted(
            &cost,
            self.profiles,
            &ctx.slos,
            &self.orders,
            &ctx.arrival_hint,
        );
        // Final per-task selection re-derived through the variant
        // provider under the committed order — bit-identical to
        // Algorithm 1 step 3 for the enumerated provider (same Θᵗ,
        // same strict-improvement scan under p⃗*).
        let mut selections: BTreeMap<String, Option<Selection>> = BTreeMap::new();
        for name in alg1.selections.keys() {
            let Some(slo) = ctx.slos.get(name) else { continue };
            let q = VariantQuery {
                task: name.clone(),
                slo: *slo,
                feasible_orders: Vec::new(),
                commit_order: Some(alg1.order.clone()),
                batch: cost.hint_for(name),
                pool_share: u64::MAX,
                phase: 0,
                pressure: None,
            };
            selections
                .insert(name.clone(), self.provider.provide(&q).map(|d| d.selection));
        }
        let universe = ctx.effective_universe();
        let pairs = self.hotness_pairs(&ctx.slos, &universe)?;
        let refs: Vec<(&TaskZoo, &Hotness)> =
            pairs.iter().map(|(tz, h)| (*tz, h)).collect();
        // Budgets follow served heat: Eq. 7 hotness × the arrival hint
        // (live telemetry when the context came from
        // `Telemetry::plan_context`; 1.0 everywhere when unhinted).
        let task_budgets = memory::split_budget_by_hotness_weighted(
            &refs,
            ctx.memory_budget,
            &ctx.arrival_hint,
        );
        let preload = memory::preload(&refs, ctx.memory_budget);
        Ok(Plan {
            order: alg1.order,
            selections,
            mean_latency_ms: alg1.mean_latency_ms,
            preload,
            task_budgets,
        })
    }

    fn replan(&self, prior: &ShardPlan, observed: &ShardObservation) -> Option<Migration> {
        if prior.shards < 2 || observed.movable.is_empty() {
            return None;
        }
        let from = observed.saturated;
        // Victim: the hottest movable task on the saturated shard —
        // Eq. 7 mass (cached; Ψ and Ω are fixed per planner instance)
        // weighted by the observed arrival rate, so the task actually
        // driving the backlog moves first. Missing estimates weigh 1.0
        // (pure memory hotness, the pre-telemetry behavior).
        let mut victim: Option<(f64, &String)> = None;
        for name in &observed.movable {
            let Some(h) = self.hotness_of(name, &prior.universe) else { continue };
            let traffic = observed
                .arrival_qps
                .get(name)
                .copied()
                .unwrap_or(1.0)
                .max(0.0);
            let mass = memory::hotness_mass(&h) * traffic;
            if victim.map(|(m, _)| mass > m).unwrap_or(true) {
                victim = Some((mass, name));
            }
        }
        let (_, task) = victim?;
        // Target: the least-loaded other shard.
        let mut target: Option<(f64, usize)> = None;
        for (i, &backlog) in observed.shard_backlog_ms.iter().enumerate() {
            if i == from || i >= prior.shards {
                continue;
            }
            if target.map(|(b, _)| backlog < b).unwrap_or(true) {
                target = Some((backlog, i));
            }
        }
        let (target_backlog, to) = target?;
        // A move must actually relieve pressure: never migrate onto a
        // shard at least as backed up as the saturated one.
        if target_backlog >= observed.shard_backlog_ms.get(from).copied().unwrap_or(0.0)
        {
            return None;
        }
        let selection = self.reselect(task, prior, observed, to);
        Some(Migration { task: task.clone(), from, to, selection })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixtures;

    fn ctx_for(
        profiles: &BTreeMap<String, TaskProfile>,
        budget: u64,
    ) -> PlanContext {
        let slos: BTreeMap<String, Slo> = profiles
            .keys()
            .map(|n| (n.clone(), Slo { min_accuracy: 0.5, max_latency_ms: 1e9 }))
            .collect();
        PlanContext::new(slos, budget)
    }

    #[test]
    fn plan_covers_tasks_and_splits_budget() {
        let (zoo, lm, profiles) = fixtures::trio();
        let planner = SparsityAwarePlanner::new(&zoo, &lm, &profiles);
        let ctx = ctx_for(&profiles, 100_000);
        let plan = planner.plan(&ctx).unwrap();
        assert_eq!(plan.selections.len(), 3);
        assert!(plan.selections.values().all(|s| s.is_some()));
        assert!(planner.orders().contains(&plan.order));
        assert_eq!(plan.task_budgets.values().sum::<u64>(), 100_000);
        assert!(plan.preload.total_bytes <= 100_000);
        assert!(plan.mean_latency_ms.is_finite());
    }

    #[test]
    fn synthesis_mode_plans_identically_without_pressure() {
        // `--synthesize` must not perturb startup planning: without a
        // PressureSignal the synthesizing provider delegates to the
        // enumerated path, so whole plans stay bit-identical.
        let (zoo, lm, profiles) = fixtures::trio();
        let base = SparsityAwarePlanner::new(&zoo, &lm, &profiles);
        let synth = SparsityAwarePlanner::new(&zoo, &lm, &profiles).with_synthesis();
        let ctx = ctx_for(&profiles, 100_000).with_default_batch_hint(2.0);
        let a = base.plan(&ctx).unwrap();
        let b = synth.plan(&ctx).unwrap();
        assert_eq!(a.order, b.order);
        assert_eq!(a.selections.len(), b.selections.len());
        for (name, sa) in &a.selections {
            let sb = b.selections[name];
            assert_eq!(sa.map(|s| s.stitched_index), sb.map(|s| s.stitched_index));
            assert_eq!(
                sa.map(|s| s.latency_ms.to_bits()),
                sb.map(|s| s.latency_ms.to_bits())
            );
        }
    }

    #[test]
    fn batch_hints_never_improve_planned_latency() {
        let (zoo, lm, profiles) = fixtures::trio();
        let planner = SparsityAwarePlanner::new(&zoo, &lm, &profiles);
        let unit = planner.plan(&ctx_for(&profiles, u64::MAX)).unwrap();
        let batched = planner
            .plan(&ctx_for(&profiles, u64::MAX).with_default_batch_hint(4.0))
            .unwrap();
        // Same candidates at scaled cost: the batch-aware mean is the
        // batch factor times the batch-1 mean or worse.
        assert!(batched.mean_latency_ms >= unit.mean_latency_ms - 1e-9);
    }

    #[test]
    fn replan_moves_hottest_to_least_loaded() {
        let (zoo, lm, profiles) = fixtures::trio();
        let planner = SparsityAwarePlanner::new(&zoo, &lm, &profiles);
        let slos: BTreeMap<String, Slo> = profiles
            .keys()
            .map(|n| (n.clone(), Slo { min_accuracy: 0.5, max_latency_ms: 60.0 }))
            .collect();
        let prior = ShardPlan {
            assignment: BTreeMap::from([
                ("alpha".to_string(), 0),
                ("beta".to_string(), 0),
                ("gamma".to_string(), 1),
            ]),
            shards: 3,
            slos: slos.clone(),
            universe: slos.values().copied().collect(),
        };
        // The target (shard 2) commits to the first order in Ω; the
        // re-selection must be judged under exactly that order.
        let target_order = planner.orders()[0].clone();
        let observed = ShardObservation {
            saturated: 0,
            shard_backlog_ms: vec![900.0, 50.0, 10.0],
            shard_orders: vec![Vec::new(), Vec::new(), target_order.clone()],
            shard_pool_bytes: vec![1_000_000; 3],
            movable: vec!["alpha".to_string(), "beta".to_string()],
            mean_batch: BTreeMap::new(),
            arrival_qps: BTreeMap::new(),
        };
        let mig = planner.replan(&prior, &observed).expect("must migrate");
        assert_eq!(mig.from, 0);
        assert_eq!(mig.to, 2, "least-loaded shard wins");
        assert!(["alpha", "beta"].contains(&mig.task.as_str()));
        let sel = mig.selection.expect("feasible re-selection");
        assert!(sel.accuracy >= 0.5);
        // The re-selected variant is runnable under the target's order.
        let p = &profiles[&mig.task];
        assert!(p
            .latency_est(&p.space.composition(sel.stitched_index), &target_order)
            .is_some());

        // No migration when every other shard is at least as loaded…
        let worse = ShardObservation {
            shard_backlog_ms: vec![900.0, 900.0, 1_200.0],
            ..observed.clone()
        };
        assert!(planner.replan(&prior, &worse).is_none());
        // …or when nothing is movable.
        let drained = ShardObservation { movable: Vec::new(), ..observed.clone() };
        assert!(planner.replan(&prior, &drained).is_none());

        // Telemetry steers the victim: with observed traffic heavily
        // skewed onto one movable task, that task moves regardless of
        // which has the larger raw Eq. 7 mass.
        for flooded in ["alpha", "beta"] {
            let other = if flooded == "alpha" { "beta" } else { "alpha" };
            let rates = BTreeMap::from([
                (flooded.to_string(), 200.0),
                (other.to_string(), 0.5),
            ]);
            let skewed = ShardObservation { arrival_qps: rates, ..observed.clone() };
            let mig = planner.replan(&prior, &skewed).expect("must migrate");
            assert_eq!(
                mig.task, flooded,
                "the traffic-flooded task must be the victim"
            );
        }
    }
}
