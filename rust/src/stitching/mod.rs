//! Model stitching (paper §3.1): the V^S stitched-variant space.
//!
//! A stitched variant `ṽ^{t,k}` is a composition `(i₁, …, i_S)` — at
//! subgraph position j it reuses subgraph `s_j^{t,i_j}` of original
//! variant i_j (Eq. 1). Because every variant of a task shares the
//! layer-aligned interface shapes, any composition is shape-safe; no
//! retraining, no new weights — the stitched space is purely
//! combinatorial over existing subgraphs.
//!
//! The canonical index is the base-V big-endian digit encoding
//! `k = ((i₁·V)+i₂)·V+i₃` (S=3 shown; general below), matching the
//! python oracle exporter (`aot.py`).

use crate::zoo::{TaskZoo, VariantType};

/// Typed failures of the V^S index arithmetic — the `Result` error the
/// static analyzer (`crate::analysis`) consumes instead of a panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StitchError {
    /// V = 0 or S = 0: the space has no compositions to index.
    Degenerate { v: usize, s: usize },
    /// `k ≥ V^S`: the index does not decode to S base-V digits.
    IndexOutOfRange { k: usize, v: usize, s: usize },
    /// `V^S` (or `V^{S-1}`) does not fit in `usize` — the silent
    /// release-mode wrap `pow` used to allow.
    SpaceOverflow { v: usize, s: usize },
}

impl std::fmt::Display for StitchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StitchError::Degenerate { v, s } => {
                write!(f, "degenerate stitch space V={v}, S={s}")
            }
            StitchError::IndexOutOfRange { k, v, s } => {
                write!(f, "index {k} out of range for V={v}, S={s}")
            }
            StitchError::SpaceOverflow { v, s } => {
                write!(f, "V^S overflows usize for V={v}, S={s}")
            }
        }
    }
}

impl std::error::Error for StitchError {}

/// A stitched variant: which original variant supplies each subgraph.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Composition(pub Vec<usize>);

impl Composition {
    /// Decode from the canonical base-V index. Fails (typed, no panic)
    /// on a degenerate space or an out-of-range index — the analyzer's
    /// plan-feasibility pass relies on this to reject bad plans before
    /// serving starts.
    pub fn from_index(k: usize, v: usize, s: usize) -> Result<Composition, StitchError> {
        if v == 0 || s == 0 {
            return Err(StitchError::Degenerate { v, s });
        }
        let mut digits = vec![0usize; s];
        let mut rem = k;
        for j in (0..s).rev() {
            digits[j] = rem % v;
            rem /= v;
        }
        if rem != 0 {
            return Err(StitchError::IndexOutOfRange { k, v, s });
        }
        Ok(Composition(digits))
    }

    /// Encode to the canonical base-V index.
    pub fn to_index(&self, v: usize) -> usize {
        self.0.iter().fold(0, |acc, &d| {
            debug_assert!(d < v);
            acc * v + d
        })
    }

    /// Is this a pure (non-stitched) variant — all subgraphs from one i?
    pub fn is_pure(&self) -> bool {
        self.0.windows(2).all(|w| w[0] == w[1])
    }

    pub fn subgraphs(&self) -> usize {
        self.0.len()
    }

    /// Paper-style label like "P-Q-D" from the zoo's variant types.
    ///
    /// Returns a lazy `Display` adapter instead of a `String`: the
    /// synthesis search loop labels every scored candidate, and a
    /// per-candidate `Vec<String>` + `join` allocation storm there is
    /// pure churn. `to_string()` it only where an owned label is
    /// actually stored.
    pub fn label<'a>(&'a self, zoo: &'a TaskZoo) -> impl std::fmt::Display + 'a {
        DisplayJoined {
            comp: self,
            zoo,
            f: |zoo, i, out| write!(out, "{}", zoo.variants[i].spec.vtype.tag()),
        }
    }

    /// Long label like "unstr80-int8-dense". Lazy like
    /// [`Composition::label`] — formats straight into the caller's
    /// buffer.
    pub fn name<'a>(&'a self, zoo: &'a TaskZoo) -> impl std::fmt::Display + 'a {
        DisplayJoined {
            comp: self,
            zoo,
            f: |zoo, i, out| out.write_str(&zoo.variants[i].spec.name),
        }
    }
}

/// `Display` adapter joining one rendered item per composition digit
/// with `-`, without any intermediate allocation.
struct DisplayJoined<'a> {
    comp: &'a Composition,
    zoo: &'a TaskZoo,
    f: fn(&TaskZoo, usize, &mut std::fmt::Formatter<'_>) -> std::fmt::Result,
}

impl std::fmt::Display for DisplayJoined<'_> {
    fn fmt(&self, out: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (j, &i) in self.comp.0.iter().enumerate() {
            if j > 0 {
                out.write_str("-")?;
            }
            (self.f)(self.zoo, i, out)?;
        }
        Ok(())
    }
}

/// The stitched-variant space of one task.
#[derive(Clone, Copy, Debug)]
pub struct StitchSpace {
    /// V — original variants per task.
    pub n_variants: usize,
    /// S — subgraph positions.
    pub n_subgraphs: usize,
}

impl StitchSpace {
    pub fn new(n_variants: usize, n_subgraphs: usize) -> Self {
        assert!(n_variants > 0 && n_subgraphs > 0);
        Self { n_variants, n_subgraphs }
    }

    pub fn for_task(zoo: &TaskZoo) -> Self {
        Self::new(zoo.n_variants(), zoo.iface.len() - 1)
    }

    /// |space| = V^S. Panics (with a typed message, never a silent
    /// release-mode wrap) when V^S overflows `usize`; use
    /// [`StitchSpace::try_len`] to handle that case.
    pub fn len(&self) -> usize {
        self.try_len().expect("stitch space size")
    }

    /// |space| = V^S via `checked_pow`: `Err(SpaceOverflow)` instead of
    /// the silent wraparound unchecked `pow` produces in release builds.
    pub fn try_len(&self) -> Result<usize, StitchError> {
        let (v, s) = (self.n_variants, self.n_subgraphs);
        if v == 0 || s == 0 {
            return Err(StitchError::Degenerate { v, s });
        }
        u32::try_from(s)
            .ok()
            .and_then(|s32| v.checked_pow(s32))
            .ok_or(StitchError::SpaceOverflow { v, s })
    }

    pub fn is_empty(&self) -> bool {
        false // V ≥ 1 and S ≥ 1 always yield at least one composition
    }

    /// Decode index `k`, panicking on out-of-range — internal call
    /// sites guarantee `k < len()`. External inputs (plan files,
    /// analyzer probes) should go through [`Composition::from_index`]
    /// and handle the `Result`.
    pub fn composition(&self, k: usize) -> Composition {
        Composition::from_index(k, self.n_variants, self.n_subgraphs)
            .expect("stitched index in range")
    }

    pub fn index(&self, c: &Composition) -> usize {
        assert_eq!(c.subgraphs(), self.n_subgraphs);
        c.to_index(self.n_variants)
    }

    /// Index of the pure composition of original variant i.
    pub fn pure_index(&self, i: usize) -> usize {
        self.index(&Composition(vec![i; self.n_subgraphs]))
    }

    /// Iterate all V^S compositions in canonical order.
    pub fn iter(&self) -> impl Iterator<Item = Composition> + '_ {
        (0..self.len()).map(move |k| self.composition(k))
    }

    /// How many compositions contain original-variant subgraph (j, i)?
    /// (V^{S-1} — each other position free; used by hotness sanity
    /// tests.) Checked like [`StitchSpace::len`]: panics on overflow
    /// instead of wrapping silently.
    pub fn occurrences_per_subgraph(&self) -> usize {
        let (v, s) = (self.n_variants, self.n_subgraphs);
        if v == 0 || s == 0 {
            return 0;
        }
        u32::try_from(s - 1)
            .ok()
            .and_then(|s32| v.checked_pow(s32))
            .ok_or(StitchError::SpaceOverflow { v, s })
            .expect("per-subgraph occurrence count")
    }
}

/// Mixing profile of a composition over variant *types* — e.g. how many
/// subgraph positions come from pruned vs quantized vs dense variants.
/// Feeds the accuracy estimator's feature vector.
pub fn type_histogram(c: &Composition, zoo: &TaskZoo) -> [usize; 5] {
    let mut h = [0usize; 5];
    for &i in &c.0 {
        let idx = match zoo.variants[i].spec.vtype {
            VariantType::Dense => 0,
            VariantType::Fp16 => 1,
            VariantType::Int8 => 2,
            VariantType::Unstructured => 3,
            VariantType::Structured => 4,
        };
        h[idx] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip_exhaustive() {
        let sp = StitchSpace::new(10, 3);
        assert_eq!(sp.len(), 1000);
        for k in 0..sp.len() {
            let c = sp.composition(k);
            assert_eq!(sp.index(&c), k);
        }
    }

    #[test]
    fn canonical_order_matches_python_oracle() {
        // aot.py: k = ((i1*V)+i2)*V+i3
        let sp = StitchSpace::new(10, 3);
        let c = Composition(vec![3, 1, 4]);
        assert_eq!(sp.index(&c), (3 * 10 + 1) * 10 + 4);
        assert_eq!(sp.composition(314), c);
    }

    #[test]
    fn pure_detection() {
        assert!(Composition(vec![2, 2, 2]).is_pure());
        assert!(!Composition(vec![2, 2, 3]).is_pure());
        assert!(Composition(vec![5]).is_pure());
    }

    #[test]
    fn pure_index_diagonal() {
        let sp = StitchSpace::new(10, 3);
        assert_eq!(sp.pure_index(0), 0);
        assert_eq!(sp.pure_index(7), (7 * 10 + 7) * 10 + 7);
    }

    #[test]
    fn iterator_covers_space_once() {
        let sp = StitchSpace::new(3, 2);
        let all: Vec<_> = sp.iter().collect();
        assert_eq!(all.len(), 9);
        let uniq: std::collections::HashSet<_> = all.iter().cloned().collect();
        assert_eq!(uniq.len(), 9);
    }

    #[test]
    fn occurrences_per_subgraph_formula() {
        assert_eq!(StitchSpace::new(10, 3).occurrences_per_subgraph(), 100);
        assert_eq!(StitchSpace::new(4, 2).occurrences_per_subgraph(), 4);
    }

    #[test]
    fn out_of_range_index_is_typed_error() {
        assert_eq!(
            Composition::from_index(1000, 10, 3),
            Err(StitchError::IndexOutOfRange { k: 1000, v: 10, s: 3 })
        );
        assert_eq!(
            Composition::from_index(999, 10, 3),
            Ok(Composition(vec![9, 9, 9]))
        );
        assert_eq!(
            Composition::from_index(0, 0, 3),
            Err(StitchError::Degenerate { v: 0, s: 3 })
        );
    }

    #[test]
    fn labels_render_without_intermediate_allocation() {
        let (zoo, _lm, _profiles) = crate::fixtures::trio();
        let tz = zoo.task("alpha").unwrap();
        let comp = Composition(vec![0, 1]);
        // dense at position 0, int8 at position 1 (fixture order).
        assert_eq!(comp.label(tz).to_string(), "D-Q");
        assert_eq!(
            comp.name(tz).to_string(),
            format!("{}-{}", tz.variants[0].spec.name, tz.variants[1].spec.name)
        );
        // The adapter is `Display`, so it formats straight into an
        // existing buffer — the hot-loop usage pattern.
        use std::fmt::Write as _;
        let mut buf = String::new();
        write!(buf, "{}", comp.label(tz)).unwrap();
        assert_eq!(buf, "D-Q");
    }

    #[test]
    fn space_size_overflow_is_typed_not_silent() {
        // 2^BITS overflows usize by exactly one bit.
        let sp = StitchSpace { n_variants: 2, n_subgraphs: usize::BITS as usize };
        assert_eq!(
            sp.try_len(),
            Err(StitchError::SpaceOverflow {
                v: 2,
                s: usize::BITS as usize
            })
        );
        // The largest power that still fits decodes fine.
        let ok = StitchSpace { n_variants: 2, n_subgraphs: usize::BITS as usize - 1 };
        assert_eq!(ok.try_len(), Ok(1usize << (usize::BITS - 1)));
        // Degenerate shapes are typed too (struct literals can bypass
        // the constructor's assert).
        let degenerate = StitchSpace { n_variants: 0, n_subgraphs: 2 };
        assert_eq!(
            degenerate.try_len(),
            Err(StitchError::Degenerate { v: 0, s: 2 })
        );
    }
}
